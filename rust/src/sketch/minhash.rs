//! Classical MinHash (Algorithm 1) — the K-independent-permutation
//! baseline C-MinHash replaces.
//!
//! Deliberately stores the full K × D permutation matrix: the O(K·D)
//! memory footprint *is* the paper's motivation, and the benchmarks
//! report it (`hasher_hotpath` prints bytes/hasher alongside ns/sketch).

use super::perm::{Perm, Role};
use super::Sketcher;

/// Classical MinHash with K independent permutations.
///
/// ```
/// use cminhash::sketch::{ClassicMinHasher, Sketcher};
/// let h = ClassicMinHasher::new(256, 8, 7);        // D, K, seed
/// assert_eq!(h.sketch_sparse(&[1, 100, 200]).len(), 8);
/// // the memory footprint the paper eliminates: K × D × 4 bytes
/// assert_eq!(h.perm_bytes(), 8 * 256 * 4);
/// ```
#[derive(Clone, Debug)]
pub struct ClassicMinHasher {
    d: usize,
    k: usize,
    /// Row-major K × D permutation matrix.
    perms: Vec<u32>,
}

impl ClassicMinHasher {
    /// Seeded constructor: K independent Fisher–Yates permutations.
    pub fn new(d: usize, k: usize, seed: u64) -> Self {
        let perms = (0..k as u32)
            .flat_map(|i| Perm::generate(d, seed, Role::Classic(i)).values().to_vec())
            .collect();
        ClassicMinHasher { d, k, perms }
    }

    /// Explicit permutation rows (each validated, all length D).
    pub fn from_perms(rows: &[Perm]) -> crate::Result<Self> {
        let k = rows.len();
        if k == 0 {
            return Err(crate::Error::Invalid("need at least one permutation".into()));
        }
        let d = rows[0].len();
        let mut perms = Vec::with_capacity(k * d);
        for row in rows {
            if row.len() != d {
                return Err(crate::Error::Invalid(
                    "permutation rows have inconsistent lengths".into(),
                ));
            }
            perms.extend_from_slice(row.values());
        }
        Ok(ClassicMinHasher { d, k, perms })
    }

    /// Memory held by the permutation matrix, in bytes — the quantity
    /// the paper's "2 permutations" pitch eliminates.
    pub fn perm_bytes(&self) -> usize {
        self.perms.len() * std::mem::size_of::<u32>()
    }
}

impl Sketcher for ClassicMinHasher {
    fn dim(&self) -> usize {
        self.d
    }

    fn num_hashes(&self) -> usize {
        self.k
    }

    fn sketch_sparse(&self, nonzeros: &[u32]) -> Vec<u32> {
        let mut out = vec![self.d as u32; self.k];
        for (ki, o) in out.iter_mut().enumerate() {
            let row = &self.perms[ki * self.d..(ki + 1) * self.d];
            for &s in nonzeros {
                let v = row[s as usize];
                if v < *o {
                    *o = v;
                }
            }
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn single_permutation_first_nonzero_semantics() {
        // With one permutation the hash is min over nonzeros of pi[s].
        let pi = Perm::from_values(vec![4, 0, 3, 1, 2]).unwrap();
        let h = ClassicMinHasher::from_perms(&[pi]).unwrap();
        assert_eq!(h.sketch_sparse(&[0, 2]), vec![3]);
        assert_eq!(h.sketch_sparse(&[1]), vec![0]);
        assert_eq!(h.sketch_sparse(&[]), vec![5]);
    }

    #[test]
    fn hashes_are_within_range_and_deterministic() {
        let h = ClassicMinHasher::new(100, 20, 9);
        let a = h.sketch_sparse(&[1, 50, 99]);
        let b = h.sketch_sparse(&[1, 50, 99]);
        assert_eq!(a, b);
        assert!(a.iter().all(|&v| v < 100));
    }

    #[test]
    fn memory_footprint_scales_with_k() {
        let h1 = ClassicMinHasher::new(256, 4, 0);
        let h2 = ClassicMinHasher::new(256, 8, 0);
        assert_eq!(h2.perm_bytes(), 2 * h1.perm_bytes());
    }

    #[test]
    fn from_perms_validates() {
        let a = Perm::identity(4);
        let b = Perm::identity(5);
        assert!(ClassicMinHasher::from_perms(&[a.clone(), b]).is_err());
        assert!(ClassicMinHasher::from_perms(&[]).is_err());
        assert!(ClassicMinHasher::from_perms(&[a]).is_ok());
    }
}
