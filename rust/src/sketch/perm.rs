//! Seeded random permutations.
//!
//! The paper's practical pitch is that **two** permutations (σ, π) are
//! all you ever store — even at D = 2³⁰, two u32 arrays fit in GPU/host
//! memory where K = 1024 of them would not.  This module is the single
//! place permutations are created so that Rust, the artifacts, and the
//! tests all agree: a `Perm` is a value array `p[i] ∈ 0..D` produced by
//! Fisher–Yates under a Xoshiro256++ stream seeded from `(seed, role)`.

use crate::util::rng::Rng;

/// Role tags keep σ, π and the classic-MinHash rows on independent
/// streams derived from one user seed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    /// Initial permutation σ (Algorithm 3).
    Sigma,
    /// Circulant permutation π (Algorithms 2 and 3).
    Pi,
    /// The binning permutation of the OPH family (full-length for OPH,
    /// length D/K for C-OPH).
    Oph,
    /// The i-th independent permutation of classical MinHash.
    Classic(u32),
}

impl Role {
    fn stream(self) -> u64 {
        match self {
            Role::Sigma => 0x5157_a5a5_0000_0001,
            Role::Pi => 0x5157_a5a5_0000_0002,
            Role::Oph => 0x5157_a5a5_0000_0003,
            Role::Classic(i) => 0x5157_a5a5_1000_0000 ^ u64::from(i),
        }
    }
}

/// A permutation of `0..d` stored as a value array.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Perm {
    values: Vec<u32>,
}

impl Perm {
    /// Deterministic Fisher–Yates permutation of `0..d` for `(seed, role)`.
    pub fn generate(d: usize, seed: u64, role: Role) -> Self {
        let mut rng = Rng::seed_from_u64(seed ^ role.stream());
        let mut values: Vec<u32> = (0..d as u32).collect();
        // Explicit Fisher–Yates over the in-tree Xoshiro256++ stream, so
        // the byte-exact permutation sequence is pinned by this crate.
        for i in (1..d).rev() {
            let j = rng.below(i as u64 + 1) as usize;
            values.swap(i, j);
        }
        Perm { values }
    }

    /// Wrap an explicit value array (validated to be a bijection).
    pub fn from_values(values: Vec<u32>) -> crate::Result<Self> {
        let d = values.len();
        let mut seen = vec![false; d];
        for &v in &values {
            if (v as usize) >= d || seen[v as usize] {
                return Err(crate::Error::Invalid(format!(
                    "not a permutation of 0..{d}"
                )));
            }
            seen[v as usize] = true;
        }
        Ok(Perm { values })
    }

    /// Identity permutation.
    pub fn identity(d: usize) -> Self {
        Perm {
            values: (0..d as u32).collect(),
        }
    }

    /// Dimensionality D.
    pub fn len(&self) -> usize {
        self.values.len()
    }

    /// True iff D == 0.
    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    /// Value array view.
    pub fn values(&self) -> &[u32] {
        &self.values
    }

    /// `p[i]`.
    #[inline]
    pub fn at(&self, i: usize) -> u32 {
        self.values[i]
    }

    /// Inverse permutation: `inv[p[i]] = i`.
    pub fn inverse(&self) -> Perm {
        let mut inv = vec![0u32; self.values.len()];
        for (i, &v) in self.values.iter().enumerate() {
            inv[v as usize] = i as u32;
        }
        Perm { values: inv }
    }

    /// The doubled array `p ‖ p` used by the circulant hot loop
    /// (`pi[(i - k) mod D] == doubled[i - k + D]`, no modular math).
    pub fn doubled(&self) -> Vec<u32> {
        let mut out = Vec::with_capacity(2 * self.values.len());
        out.extend_from_slice(&self.values);
        out.extend_from_slice(&self.values);
        out
    }

    /// Doubled array as i32 (the artifact input dtype).
    pub fn doubled_i32(&self) -> Vec<i32> {
        self.doubled().into_iter().map(|v| v as i32).collect()
    }

    /// The tripled array `p ‖ p ‖ [D]*D` used by the *sparse* kernel:
    /// padding indices `2D` land in the sentinel tail and contribute
    /// the empty-hash value D.
    pub fn tripled_sentinel_i32(&self) -> Vec<i32> {
        let d = self.values.len();
        let mut out = Vec::with_capacity(3 * d);
        out.extend(self.values.iter().map(|&v| v as i32));
        out.extend(self.values.iter().map(|&v| v as i32));
        out.extend(std::iter::repeat(d as i32).take(d));
        out
    }

    /// Values as i32 (the artifact input dtype).
    pub fn values_i32(&self) -> Vec<i32> {
        self.values.iter().map(|&v| v as i32).collect()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn generate_is_bijection() {
        for d in [1usize, 2, 17, 256, 1000] {
            let p = Perm::generate(d, 42, Role::Pi);
            let mut seen = vec![false; d];
            for &v in p.values() {
                assert!(!seen[v as usize]);
                seen[v as usize] = true;
            }
        }
    }

    #[test]
    fn generate_is_deterministic_and_role_separated() {
        let a = Perm::generate(100, 7, Role::Sigma);
        let b = Perm::generate(100, 7, Role::Sigma);
        let c = Perm::generate(100, 7, Role::Pi);
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(
            Perm::generate(100, 7, Role::Classic(0)),
            Perm::generate(100, 7, Role::Classic(1))
        );
    }

    #[test]
    fn inverse_roundtrips() {
        let p = Perm::generate(50, 3, Role::Pi);
        let inv = p.inverse();
        for i in 0..50 {
            assert_eq!(inv.at(p.at(i) as usize), i as u32);
        }
    }

    #[test]
    fn from_values_rejects_non_bijections() {
        assert!(Perm::from_values(vec![0, 0, 1]).is_err());
        assert!(Perm::from_values(vec![0, 3]).is_err());
        assert!(Perm::from_values(vec![2, 0, 1]).is_ok());
    }

    #[test]
    fn doubled_indexing_identity() {
        let p = Perm::generate(31, 9, Role::Pi);
        let d2 = p.doubled();
        let d = 31i64;
        for i in 0..31i64 {
            for k in 1..=31i64 {
                let m = ((i - k) % d + d) % d;
                assert_eq!(d2[(i - k + d) as usize], p.at(m as usize));
            }
        }
    }
}
