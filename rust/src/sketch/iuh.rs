//! Iterative universal hashing (`iuh`): MinHash from O(1) state per
//! hash family instead of O(D) permutation tables.
//!
//! Every other scheme in the registry stores at least one explicit
//! length-D permutation (`sketch/perm.rs`), so memory grows with the
//! data dimensionality.  Following the iterative universal hash
//! generator of arXiv:1401.6124 — where each hash function is obtained
//! from the previous one by a constant-time key update rather than a
//! fresh table — this scheme keeps **O(1) state total**: two odd
//! multipliers, two shift amounts, and a per-slot key advanced by one
//! modular addition (`key += gamma`) between the K hash functions.
//! That makes web-scale D feasible where materialising σ/π does not.
//!
//! Each slot k applies a keyed bijection of `0..2^w` (w = the number of
//! bits covering D):
//!
//! ```text
//! mix(x) = xorshift(odd-mul(xorshift(odd-mul(x))))   (all mod 2^w)
//! h_k(s) = mix((s + key_k) mod 2^w)
//! ```
//!
//! Odd multiplication mod `2^w` and `x ^= x >> s` are each bijections,
//! so `mix` is a true permutation of `0..2^w`.  When D is not a power
//! of two the value is **cycle-walked** — re-mixed until it lands below
//! D — which restricts the bijection to a permutation of `0..D`
//! (injectivity: walking is deterministic and invertible step by step;
//! termination: the orbit of any start point returns into `0..D`).
//! Since `2^(w-1) < D <= 2^w`, a walk takes < 2 extra steps in
//! expectation; for power-of-two D (the common case in this tree) the
//! walk loop is compiled out entirely and the inner K-loop is
//! branch-free.
//!
//! Because every slot hashes through a true permutation of `0..D`, the
//! collision estimator is unbiased exactly as for classical MinHash;
//! `rust/tests/scheme_consistency.rs` holds this to a 5σ gate.

use super::Sketcher;
use crate::util::rng::splitmix64;

/// Domain-separation constant for the key-material stream ("IUH_MINH"),
/// so `iuh` sketches are uncorrelated with the permutation streams other
/// schemes derive from the same seed.
const IUH_STREAM: u64 = 0x4955_485F_4D49_4E48;

/// MinHash via iterative universal hashing (arXiv:1401.6124): K keyed
/// bijections of `0..D` generated from O(1) state, each key obtained
/// from the previous by one modular addition.
///
/// ```
/// use cminhash::sketch::{IuhHasher, Sketcher};
/// let h = IuhHasher::new(64, 16, 42);
/// let sk = h.sketch_sparse(&[1, 5, 40]);
/// assert_eq!(sk.len(), 16);
/// assert!(sk.iter().all(|&v| v < 64));
/// assert_eq!(sk, h.sketch_sparse(&[1, 5, 40])); // deterministic
/// ```
pub struct IuhHasher {
    d: usize,
    k: usize,
    /// `2^w - 1` where `2^w` is the smallest power of two >= D.
    mask: u32,
    /// D is a power of two: the cycle-walk loop is statically dead.
    pow2: bool,
    m1: u32,
    m2: u32,
    s1: u32,
    s2: u32,
    key0: u32,
    gamma: u32,
}

impl IuhHasher {
    /// Build for dimension `d`, `k` hashes, and a seed.  Requires
    /// `1 <= k <= d` (the registry-wide shape contract).
    pub fn new(d: usize, k: usize, seed: u64) -> Self {
        assert!(k >= 1 && k <= d, "need 1 <= K <= D, got K={k}, D={d}");
        let pow = d.next_power_of_two();
        let w = pow.trailing_zeros();
        let mask = (pow as u64 - 1) as u32;
        let mut state = seed ^ IUH_STREAM;
        let m1 = (splitmix64(&mut state) as u32) | 1;
        let m2 = (splitmix64(&mut state) as u32) | 1;
        let key0 = (splitmix64(&mut state) as u32) & mask;
        let gamma = ((splitmix64(&mut state) as u32) | 1) & mask;
        IuhHasher {
            d,
            k,
            mask,
            pow2: d == pow,
            m1,
            m2,
            s1: ((w + 1) / 2).max(1),
            s2: (w / 2).max(1),
            key0,
            gamma,
        }
    }

    /// The keyed bijection core: two odd-multiply / xorshift rounds,
    /// everything mod `2^w`.  Both primitives are invertible, so this
    /// is a permutation of `0..=mask`.
    #[inline(always)]
    fn mix(&self, x: u32) -> u32 {
        let mut x = x.wrapping_mul(self.m1) & self.mask;
        x ^= x >> self.s1;
        x = x.wrapping_mul(self.m2) & self.mask;
        x ^= x >> self.s2;
        x
    }
}

impl Sketcher for IuhHasher {
    fn dim(&self) -> usize {
        self.d
    }

    fn num_hashes(&self) -> usize {
        self.k
    }

    fn sketch_sparse(&self, nonzeros: &[u32]) -> Vec<u32> {
        let mut out = vec![self.d as u32; self.k];
        if self.pow2 {
            // Branch-free inner loop: the walk condition `x >= d` can
            // never fire (mask == d - 1), so we elide it and keep the
            // K-loop a straight-line multiply/shift/min chain the
            // compiler can vectorise.
            for &s in nonzeros {
                debug_assert!((s as usize) < self.d, "index {s} >= D={}", self.d);
                let mut key = self.key0;
                for slot in out.iter_mut() {
                    let x = self.mix(s.wrapping_add(key) & self.mask);
                    *slot = (*slot).min(x);
                    key = key.wrapping_add(self.gamma) & self.mask;
                }
            }
        } else {
            for &s in nonzeros {
                debug_assert!((s as usize) < self.d, "index {s} >= D={}", self.d);
                let mut key = self.key0;
                for slot in out.iter_mut() {
                    let mut x = self.mix(s.wrapping_add(key) & self.mask);
                    // Cycle-walk back into 0..D; < 2 extra mixes in
                    // expectation because 2^(w-1) < D.
                    while x as usize >= self.d {
                        x = self.mix(x.wrapping_add(key) & self.mask);
                    }
                    *slot = (*slot).min(x);
                    key = key.wrapping_add(self.gamma) & self.mask;
                }
            }
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::sketch::estimate;

    /// Apply slot k's hash to a single index by sketching a singleton.
    fn slot_hash(h: &IuhHasher, s: u32, k: usize) -> u32 {
        h.sketch_sparse(&[s])[k]
    }

    #[test]
    fn every_slot_is_a_permutation_power_of_two_d() {
        let d = 64;
        let h = IuhHasher::new(d, 16, 7);
        for k in 0..16 {
            let mut seen = vec![false; d];
            for s in 0..d as u32 {
                let v = slot_hash(&h, s, k) as usize;
                assert!(v < d, "value {v} out of range");
                assert!(!seen[v], "slot {k}: value {v} repeated");
                seen[v] = true;
            }
        }
    }

    #[test]
    fn every_slot_is_a_permutation_with_cycle_walking() {
        // Non-power-of-two D exercises the walk loop; the map must
        // still be injective onto 0..D.
        for d in [48usize, 100, 7, 3] {
            let h = IuhHasher::new(d, d.min(16), 11);
            for k in 0..d.min(16) {
                let mut seen = vec![false; d];
                for s in 0..d as u32 {
                    let v = slot_hash(&h, s, k) as usize;
                    assert!(v < d, "D={d}: value {v} out of range");
                    assert!(!seen[v], "D={d} slot {k}: value {v} repeated");
                    seen[v] = true;
                }
            }
        }
    }

    #[test]
    fn degenerate_dimensions_work() {
        let h = IuhHasher::new(1, 1, 3);
        assert_eq!(h.sketch_sparse(&[0]), vec![0]);
        assert_eq!(h.sketch_sparse(&[]), vec![1]); // sentinel
        let h = IuhHasher::new(2, 2, 3);
        let sk = h.sketch_sparse(&[0, 1]);
        assert!(sk.iter().all(|&v| v < 2));
    }

    #[test]
    fn sketches_are_deterministic_in_range_and_seed_sensitive() {
        let nz: Vec<u32> = vec![3, 17, 40, 63];
        let a = IuhHasher::new(64, 16, 5);
        let b = IuhHasher::new(64, 16, 5);
        let c = IuhHasher::new(64, 16, 6);
        assert_eq!(a.sketch_sparse(&nz), b.sketch_sparse(&nz));
        assert_ne!(a.sketch_sparse(&nz), c.sketch_sparse(&nz));
        assert!(a.sketch_sparse(&nz).iter().all(|&v| v < 64));
    }

    #[test]
    fn empty_vector_keeps_sentinels() {
        let h = IuhHasher::new(64, 16, 9);
        assert!(h.sketch_sparse(&[]).iter().all(|&v| v == 64));
    }

    #[test]
    fn estimates_track_exact_jaccard_on_average() {
        // Same shape as the oph/coph averaged-bias tests: J = 1/3 at
        // D=64, K=16, averaged over 300 seeds.  Each slot hashes
        // through a true permutation of 0..D, so the collision
        // estimator is unbiased; 300 trials put the SE of the mean
        // around 0.008 and we gate at 0.04.
        let v: Vec<u32> = (0..24).collect();
        let w: Vec<u32> = (12..36).collect();
        let truth = 12.0 / 36.0;
        let trials = 300u64;
        let mut acc = 0.0;
        for seed in 0..trials {
            let h = IuhHasher::new(64, 16, seed);
            acc += estimate(&h.sketch_sparse(&v), &h.sketch_sparse(&w));
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - truth).abs() < 0.04,
            "iuh bias: mean {mean:.4} vs J {truth:.4}"
        );
    }

    #[test]
    fn walking_dimension_is_unbiased_too() {
        // D=48 forces cycle-walking on ~1/3 of mixes; bias must not
        // creep in (walking preserves the permutation property).
        let v: Vec<u32> = (0..18).collect();
        let w: Vec<u32> = (9..27).collect();
        let truth = 9.0 / 27.0;
        let trials = 300u64;
        let mut acc = 0.0;
        for seed in 0..trials {
            let h = IuhHasher::new(48, 16, seed);
            acc += estimate(&h.sketch_sparse(&v), &h.sketch_sparse(&w));
        }
        let mean = acc / trials as f64;
        assert!(
            (mean - truth).abs() < 0.04,
            "iuh walking bias: mean {mean:.4} vs J {truth:.4}"
        );
    }
}
