//! b-bit sketching (Li & König, 2011) on top of C-MinHash — the
//! storage-side companion of the paper's permutation-side saving.
//!
//! Keeping only the lowest b bits of each hash shrinks sketches by
//! 32/b× at the cost of false collisions: two *different* hash values
//! collide on their low b bits with probability ≈ 1/2^b.  The standard
//! unbiased correction inverts that mixture:
//!
//! ```text
//! E[collision_b] ≈ J + (1 − J)/2^b    (D ≫ 2^b)
//! Ĵ_b = (collision_b − 1/2^b) / (1 − 1/2^b)
//! ```
//!
//! Combining both ideas: 2 permutations *and* b-bit sketches means a
//! similarity service at D = 2³⁰, K = 1024 stores 8 GB of permutations
//! → 8 KB, and 4 KB/item sketches → 128 B/item at b = 1.
//!
//! This module is also the row codec of the serving plane's **packed
//! storage mode** (`sketch.bits` < 32): [`pack_row`]/[`unpack_row`]
//! lay K b-bit lanes into contiguous `u64` words, and
//! [`collision_count`] scores two packed rows with word-level
//! XOR + popcount — no per-lane extraction on the query hot path.

use super::Sketcher;

/// The sketch widths the serving plane accepts for `sketch.bits`.
///
/// All of them divide 64, so a lane never straddles a word boundary
/// and [`collision_count`] can run its SWAR popcount kernel.  (The
/// [`BBitSketch`] codec itself accepts any `1 ≤ b ≤ 32`; widths
/// outside this list just take the scalar scoring path.)
pub const SUPPORTED_BITS: [u8; 6] = [1, 2, 4, 8, 16, 32];

/// Validate a serving-plane sketch width (`sketch.bits` / `--bits`).
pub fn check_sketch_bits(bits: u8) -> crate::Result<()> {
    if !SUPPORTED_BITS.contains(&bits) {
        return Err(crate::Error::Invalid(format!(
            "sketch bits must be one of 1|2|4|8|16|32, got {bits}"
        )));
    }
    Ok(())
}

/// Number of `u64` words one packed row of K b-bit lanes occupies.
pub fn packed_words(k: usize, bits: u8) -> usize {
    (k * bits as usize).div_ceil(64)
}

#[inline]
fn lane_mask(bits: u8) -> u64 {
    debug_assert!((1..=32).contains(&bits));
    (1u64 << bits) - 1
}

/// Pack `full` (one b-bit lane per hash, low bits kept) into `out`,
/// which must be exactly [`packed_words`]`(full.len(), bits)` long.
/// Unused high bits of the last word are left zero, so identical
/// logical rows always produce identical words.
pub fn pack_row(full: &[u32], bits: u8, out: &mut [u64]) {
    debug_assert_eq!(out.len(), packed_words(full.len(), bits));
    for w in out.iter_mut() {
        *w = 0;
    }
    let b = bits as usize;
    let mask = lane_mask(bits);
    for (i, &h) in full.iter().enumerate() {
        let v = u64::from(h) & mask;
        let pos = i * b;
        let (w, off) = (pos / 64, pos % 64);
        out[w] |= v << off;
        if off + b > 64 {
            out[w + 1] |= v >> (64 - off);
        }
    }
}

/// Unpack a row packed by [`pack_row`] back into its K masked lane
/// values (the low b bits of the original hashes).
pub fn unpack_row(words: &[u64], k: usize, bits: u8) -> Vec<u32> {
    let b = bits as usize;
    let mask = lane_mask(bits);
    (0..k)
        .map(|i| {
            let pos = i * b;
            let (w, off) = (pos / 64, pos % 64);
            let mut v = words[w] >> off;
            if off + b > 64 {
                v |= words[w + 1] << (64 - off);
            }
            (v & mask) as u32
        })
        .collect()
}

/// Number of equal b-bit lanes between two packed rows of K lanes —
/// the packed plane's query kernel: one XOR per word, a log₂(b) OR
/// fold to collapse each lane to its low bit, one popcount.
///
/// Requires a lane width that divides 64 (every [`SUPPORTED_BITS`]
/// value qualifies), so lanes never straddle words.  Padding lanes
/// beyond K are zero in both rows by construction and are subtracted
/// out.
pub fn collision_count(a: &[u64], b: &[u64], k: usize, bits: u8) -> usize {
    // Checked (not debug_) invariants: a release-mode width mismatch
    // would silently miscount — equal-looking scores for rows of
    // different K or b.  The checks are O(1) per call against an O(wpr)
    // loop, so they are free at the index boundary where widths of
    // stored rows first meet query rows.
    assert_eq!(a.len(), b.len(), "packed rows differ in width");
    assert_eq!(
        a.len(),
        packed_words(k, bits),
        "packed row width does not match K at this lane width"
    );
    let bw = bits as usize;
    assert_eq!(64 % bw, 0, "kernel needs a word-aligned lane width");
    let lanes_per_word = 64 / bw;
    let lsb = u64::MAX / lane_mask(bits);
    let mut eq = 0usize;
    for (&x, &y) in a.iter().zip(b) {
        eq += word_equal_lanes(x, y, bw, lanes_per_word, lsb);
    }
    // Lanes past K are zero on both sides and always count as equal.
    eq - (a.len() * lanes_per_word - k)
}

/// Equal lanes in one aligned word pair: XOR, OR-fold each lane's bits
/// down onto its low bit, mask to the lane-lsb comb, popcount the
/// *differing* lanes and subtract.  At b = 1 the fold loop is empty and
/// `lsb` is all-ones, so this degenerates to `64 − popcount(x ^ y)` —
/// the 1-bit fast path needs no special case.
#[inline(always)]
fn word_equal_lanes(x: u64, y: u64, bw: usize, lanes_per_word: usize, lsb: u64) -> usize {
    let mut z = x ^ y;
    // Total shift < b, so a neighboring lane's bits can never reach
    // this lane's bit 0.
    let mut sh = 1usize;
    while sh < bw {
        z |= z >> sh;
        sh <<= 1;
    }
    lanes_per_word - (z & lsb).count_ones() as usize
}

/// Score every candidate of one band bucket against the query row in a
/// single pass: `counts[i]` = [`collision_count`]`(q, row(slots[i]))`.
///
/// This is the packed plane's batch query kernel.  Instead of one
/// `collision_count` call per candidate (function-call and bounds-check
/// overhead per row, no instruction-level parallelism across words),
/// the bucket streams rows straight out of the arena — callers pass
/// slots sorted ascending, so candidate rows are read in arena order
/// and prefetch well — and the word loop is manually unrolled 4-wide so
/// the four XOR/fold/popcount chains pipeline independently.
///
/// `arena` is the full [`crate::index::PackedRows`] word array (`wpr`
/// words per row, row-major); `slots` are row indices into it.  The
/// width invariants are checked once per call rather than once per
/// candidate.
pub fn bucket_collision_counts(
    q: &[u64],
    arena: &[u64],
    wpr: usize,
    slots: &[u64],
    k: usize,
    bits: u8,
) -> Vec<usize> {
    assert_eq!(q.len(), wpr, "packed rows differ in width");
    assert_eq!(
        wpr,
        packed_words(k, bits),
        "packed row width does not match K at this lane width"
    );
    let bw = bits as usize;
    assert_eq!(64 % bw, 0, "kernel needs a word-aligned lane width");
    if let Some(&max_slot) = slots.iter().max() {
        assert!(
            (max_slot as usize + 1) * wpr <= arena.len(),
            "slot {max_slot} out of arena bounds"
        );
    }
    let lanes_per_word = 64 / bw;
    let lsb = u64::MAX / lane_mask(bits);
    // Padding lanes beyond K are zero in the query and every stored
    // row, so they always count as equal; subtract them per row.
    let pad = wpr * lanes_per_word - k;
    let mut out = Vec::with_capacity(slots.len());
    for &slot in slots {
        let base = slot as usize * wpr;
        let row = &arena[base..base + wpr];
        let mut e0 = 0usize;
        let mut e1 = 0usize;
        let mut e2 = 0usize;
        let mut e3 = 0usize;
        let mut qw = q.chunks_exact(4);
        let mut rw = row.chunks_exact(4);
        for (qs, rs) in (&mut qw).zip(&mut rw) {
            e0 += word_equal_lanes(qs[0], rs[0], bw, lanes_per_word, lsb);
            e1 += word_equal_lanes(qs[1], rs[1], bw, lanes_per_word, lsb);
            e2 += word_equal_lanes(qs[2], rs[2], bw, lanes_per_word, lsb);
            e3 += word_equal_lanes(qs[3], rs[3], bw, lanes_per_word, lsb);
        }
        let mut eq = e0 + e1 + e2 + e3;
        for (&x, &y) in qw.remainder().iter().zip(rw.remainder()) {
            eq += word_equal_lanes(x, y, bw, lanes_per_word, lsb);
        }
        out.push(eq - pad);
    }
    out
}

/// The collision-corrected Jaccard estimate for `collisions` equal
/// lanes out of K at width `bits`:
/// Ĵ_b = (c − 2^{−b}) / (1 − 2^{−b}) clamped to [0, 1].
///
/// At `bits = 32` no information was discarded (hash values live in
/// `0..D ≤ 2³²`), so the raw collision fraction is returned untouched
/// — exactly [`super::estimate`] — keeping the full-width path
/// bit-for-bit identical to the uncompressed estimator.
pub fn corrected_estimate(collisions: usize, k: usize, bits: u8) -> f64 {
    let c = collisions as f64 / k as f64;
    if bits >= 32 {
        return c;
    }
    let r = 1.0 / (1u64 << bits) as f64;
    ((c - r) / (1.0 - r)).clamp(0.0, 1.0)
}

/// A compressed sketch: K values of b bits each, bit-packed into u64
/// words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BBitSketch {
    bits_per_hash: u8,
    k: usize,
    words: Vec<u64>,
}

impl BBitSketch {
    /// Compress a full sketch to b bits per hash (1 ≤ b ≤ 32; b = 32
    /// keeps every bit and exists so packed and full code paths share
    /// one codec).
    pub fn compress(full: &[u32], b: u8) -> Self {
        assert!((1..=32).contains(&b), "need 1 <= b <= 32");
        let k = full.len();
        let mut words = vec![0u64; packed_words(k, b)];
        pack_row(full, b, &mut words);
        BBitSketch {
            bits_per_hash: b,
            k,
            words,
        }
    }

    /// Reassemble a sketch from its packed words (the snapshot / WAL
    /// load path).  The word count must match [`packed_words`].
    pub fn from_words(b: u8, k: usize, words: Vec<u64>) -> crate::Result<Self> {
        if !(1..=32).contains(&b) {
            return Err(crate::Error::Invalid(format!(
                "bits per hash must be in 1..=32, got {b}"
            )));
        }
        if words.len() != packed_words(k, b) {
            return Err(crate::Error::Invalid(format!(
                "packed sketch has {} words, K={k} at b={b} needs {}",
                words.len(),
                packed_words(k, b)
            )));
        }
        Ok(BBitSketch {
            bits_per_hash: b,
            k,
            words,
        })
    }

    /// Number of hash slots K.
    pub fn len(&self) -> usize {
        self.k
    }

    /// True iff K == 0.
    pub fn is_empty(&self) -> bool {
        self.k == 0
    }

    /// Bits kept per hash.
    pub fn bits_per_hash(&self) -> u8 {
        self.bits_per_hash
    }

    /// Storage footprint in bytes.
    pub fn size_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// The packed words (row-major lanes, low-bit first).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// The i-th b-bit value.
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        let bits = self.bits_per_hash as usize;
        let mask = lane_mask(self.bits_per_hash);
        let pos = i * bits;
        let (w, off) = (pos / 64, pos % 64);
        let mut v = self.words[w] >> off;
        if off + bits > 64 {
            v |= self.words[w + 1] << (64 - off);
        }
        v & mask
    }

    /// Number of colliding b-bit slots (word-level XOR + popcount when
    /// b divides 64, per-lane scalar comparison otherwise).
    pub fn collisions(&self, other: &BBitSketch) -> usize {
        assert_eq!(self.k, other.k, "sketch lengths differ");
        assert_eq!(
            self.bits_per_hash, other.bits_per_hash,
            "bit widths differ"
        );
        if 64 % self.bits_per_hash as usize == 0 {
            collision_count(&self.words, &other.words, self.k, self.bits_per_hash)
        } else {
            (0..self.k).filter(|&i| self.get(i) == other.get(i)).count()
        }
    }

    /// Raw fraction of colliding b-bit slots.
    pub fn collision_fraction(&self, other: &BBitSketch) -> f64 {
        self.collisions(other) as f64 / self.k as f64
    }

    /// Unbiased-corrected Jaccard estimate
    /// Ĵ_b = (c − 2^{−b}) / (1 − 2^{−b}), clamped to [0, 1]
    /// (the raw fraction at b = 32 — see [`corrected_estimate`]).
    pub fn estimate(&self, other: &BBitSketch) -> f64 {
        corrected_estimate(self.collisions(other), self.k, self.bits_per_hash)
    }
}

/// A sketcher wrapper producing b-bit sketches directly.
pub struct BBitSketcher<S: Sketcher> {
    inner: S,
    b: u8,
}

impl<S: Sketcher> BBitSketcher<S> {
    /// Wrap a full-width sketcher (1 ≤ b ≤ 32).
    pub fn new(inner: S, b: u8) -> Self {
        assert!((1..=32).contains(&b));
        BBitSketcher { inner, b }
    }

    /// Sketch + compress in one call.
    pub fn sketch_sparse(&self, nonzeros: &[u32]) -> BBitSketch {
        BBitSketch::compress(&self.inner.sketch_sparse(nonzeros), self.b)
    }

    /// The wrapped sketcher.
    pub fn inner(&self) -> &S {
        &self.inner
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::sketch::{CMinHasher, SparseVec};
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_roundtrip() {
        let full: Vec<u32> = (0..100).map(|i| i * 37 % 1024).collect();
        for b in [1u8, 2, 3, 5, 8, 12, 16, 32] {
            let sk = BBitSketch::compress(&full, b);
            assert_eq!(sk.len(), 100);
            let mask = lane_mask(b);
            for (i, &h) in full.iter().enumerate() {
                assert_eq!(sk.get(i), u64::from(h) & mask, "b={b} i={i}");
            }
            // the free-function codec agrees with the struct
            assert_eq!(unpack_row(sk.words(), 100, b), {
                let masked: Vec<u32> =
                    full.iter().map(|&h| (u64::from(h) & mask) as u32).collect();
                masked
            });
        }
    }

    #[test]
    fn from_words_validates_width() {
        let full: Vec<u32> = (0..10).collect();
        let sk = BBitSketch::compress(&full, 4);
        let back = BBitSketch::from_words(4, 10, sk.words().to_vec()).unwrap();
        assert_eq!(back, sk);
        assert!(BBitSketch::from_words(4, 10, vec![0; 2]).is_err());
        assert!(BBitSketch::from_words(0, 10, vec![]).is_err());
        assert!(BBitSketch::from_words(33, 10, vec![0; 6]).is_err());
    }

    #[test]
    fn identical_sketches_estimate_one() {
        let full: Vec<u32> = (0..64).map(|i| i * 13).collect();
        let a = BBitSketch::compress(&full, 4);
        let b = BBitSketch::compress(&full, 4);
        assert_eq!(a.estimate(&b), 1.0);
    }

    #[test]
    fn compression_ratio() {
        let full: Vec<u32> = vec![0; 1024]; // 4 KB uncompressed
        let one_bit = BBitSketch::compress(&full, 1);
        assert_eq!(one_bit.size_bytes(), 128);
        let two_bit = BBitSketch::compress(&full, 2);
        assert_eq!(two_bit.size_bytes(), 256);
    }

    #[test]
    fn popcount_kernel_matches_scalar_count() {
        let mut rng = Rng::seed_from_u64(9);
        for b in SUPPORTED_BITS {
            for k in [1usize, 7, 16, 63, 64, 100, 129] {
                let va: Vec<u32> = (0..k).map(|_| rng.range_u32(0, 1 << 20)).collect();
                // correlate half the slots so collisions actually occur
                let vb: Vec<u32> = va
                    .iter()
                    .map(|&v| {
                        if rng.bool_with(0.5) {
                            v
                        } else {
                            rng.range_u32(0, 1 << 20)
                        }
                    })
                    .collect();
                let sa = BBitSketch::compress(&va, b);
                let sb = BBitSketch::compress(&vb, b);
                let scalar =
                    (0..k).filter(|&i| sa.get(i) == sb.get(i)).count();
                assert_eq!(
                    collision_count(sa.words(), sb.words(), k, b),
                    scalar,
                    "b={b} k={k}"
                );
            }
        }
    }

    #[test]
    fn thirty_two_bit_estimate_is_the_raw_collision_fraction() {
        // bits = 32 discards nothing: corrected == raw == estimate(),
        // exactly (no correction term, no float drift).
        let va: Vec<u32> = (0..128).map(|i| i * 31 % 512).collect();
        let vb: Vec<u32> = va
            .iter()
            .enumerate()
            .map(|(i, &v)| if i % 3 == 0 { v } else { v + 1 })
            .collect();
        let sa = BBitSketch::compress(&va, 32);
        let sb = BBitSketch::compress(&vb, 32);
        assert_eq!(sa.estimate(&sb), crate::sketch::estimate(&va, &vb));
    }

    #[test]
    fn correction_recovers_jaccard_statistically() {
        // b-bit estimate must track exact J once corrected, for several b.
        let d = 4096usize;
        let k = 2048usize;
        let v = SparseVec::new(d as u32, (0..300).map(|i| i * 10).collect()).unwrap();
        let w =
            SparseVec::new(d as u32, (100..400).map(|i| i * 10).collect()).unwrap();
        let truth = v.jaccard(&w);
        for b in [1u8, 2, 4, 8] {
            let mut acc = 0.0;
            let reps = 12;
            for seed in 0..reps {
                let hasher = BBitSketcher::new(CMinHasher::new(d, k, seed), b);
                let sa = hasher.sketch_sparse(v.indices());
                let sb = hasher.sketch_sparse(w.indices());
                acc += sa.estimate(&sb);
            }
            let est = acc / reps as f64;
            // sd ≈ sqrt(Var_b / (K reps)); generous 0.05 tolerance
            assert!(
                (est - truth).abs() < 0.05,
                "b={b}: est {est} vs truth {truth}"
            );
        }
    }

    #[test]
    fn one_bit_raw_collision_is_biased_up() {
        // Without the correction, 1-bit collisions overshoot J by
        // ≈ (1−J)/2 — the reason the correction exists.
        let d = 2048usize;
        let hasher = CMinHasher::new(d, 2048, 3);
        let v: Vec<u32> = (0..200).map(|i| i * 10).collect();
        let w: Vec<u32> = (1000..1200).map(|i| i as u32).collect(); // disjoint-ish
        let a = BBitSketch::compress(&hasher.sketch_sparse(&v), 1);
        let b = BBitSketch::compress(&hasher.sketch_sparse(&w), 1);
        let raw = a.collision_fraction(&b);
        assert!(raw > 0.3, "raw 1-bit collisions should be ~0.5, got {raw}");
        assert!(a.estimate(&b) < 0.15, "corrected estimate near 0");
    }

    /// Build a flat arena (like `PackedRows`) from full-width rows.
    fn build_arena(rows: &[Vec<u32>], k: usize, bits: u8) -> (Vec<u64>, usize) {
        let wpr = packed_words(k, bits);
        let mut arena = vec![0u64; rows.len() * wpr];
        for (slot, full) in rows.iter().enumerate() {
            pack_row(full, bits, &mut arena[slot * wpr..(slot + 1) * wpr]);
        }
        (arena, wpr)
    }

    #[test]
    fn batch_kernel_matches_scalar_collision_count() {
        // The proof-by-test the batch scorer ships under: for every
        // supported width, odd and even K (including K values whose
        // lanes cross u64 word seams), the bucket kernel returns
        // exactly what per-candidate collision_count returns.
        let mut rng = Rng::seed_from_u64(21);
        for bits in SUPPORTED_BITS {
            for k in [1usize, 7, 16, 33, 63, 64, 65, 100, 129] {
                let n = 9usize; // exercises the 4-way unroll remainder
                let rows: Vec<Vec<u32>> = (0..n)
                    .map(|_| (0..k).map(|_| rng.range_u32(0, 1 << 20)).collect())
                    .collect();
                let (arena, wpr) = build_arena(&rows, k, bits);
                // query correlated with row 0 so collisions occur
                let qfull: Vec<u32> = rows[0]
                    .iter()
                    .map(|&v| {
                        if rng.bool_with(0.5) {
                            v
                        } else {
                            rng.range_u32(0, 1 << 20)
                        }
                    })
                    .collect();
                let mut q = vec![0u64; wpr];
                pack_row(&qfull, bits, &mut q);
                let slots: Vec<u64> = (0..n as u64).collect();
                let batch = bucket_collision_counts(&q, &arena, wpr, &slots, k, bits);
                for (i, &slot) in slots.iter().enumerate() {
                    let base = slot as usize * wpr;
                    let scalar =
                        collision_count(&q, &arena[base..base + wpr], k, bits);
                    assert_eq!(batch[i], scalar, "bits={bits} k={k} slot={slot}");
                }
            }
        }
    }

    #[test]
    fn batch_kernel_handles_empty_and_singleton_buckets() {
        let k = 48usize;
        let bits = 4u8;
        let rows: Vec<Vec<u32>> = vec![(0..k as u32).collect()];
        let (arena, wpr) = build_arena(&rows, k, bits);
        let mut q = vec![0u64; wpr];
        pack_row(&rows[0], bits, &mut q);
        assert_eq!(
            bucket_collision_counts(&q, &arena, wpr, &[], k, bits),
            Vec::<usize>::new(),
            "empty bucket scores nothing"
        );
        assert_eq!(
            bucket_collision_counts(&q, &arena, wpr, &[0], k, bits),
            vec![k],
            "self-match collides on every lane"
        );
    }

    #[test]
    fn batch_kernel_scores_unsorted_and_repeated_slots() {
        // The kernel must not assume slots are sorted or unique (the
        // index sorts them for locality, but correctness is per slot).
        let k = 16usize;
        let bits = 8u8;
        let rows: Vec<Vec<u32>> = (0..4)
            .map(|r| (0..k as u32).map(|i| i * 3 + r).collect())
            .collect();
        let (arena, wpr) = build_arena(&rows, k, bits);
        let mut q = vec![0u64; wpr];
        pack_row(&rows[2], bits, &mut q);
        let got = bucket_collision_counts(&q, &arena, wpr, &[3, 0, 2, 2], k, bits);
        let want: Vec<usize> = [3u64, 0, 2, 2]
            .iter()
            .map(|&s| {
                let b = s as usize * wpr;
                collision_count(&q, &arena[b..b + wpr], k, bits)
            })
            .collect();
        assert_eq!(got, want);
        assert_eq!(got[2], k, "slot 2 is the query row itself");
    }

    #[test]
    #[should_panic(expected = "packed rows differ in width")]
    fn collision_count_rejects_width_mismatch() {
        // The release-mode silent-miscount hazard: scoring a 2-word row
        // against a 1-word row must fail loudly, not return a garbage
        // count (these asserts were debug-only once).
        let a = vec![0u64; 2];
        let b = vec![0u64; 1];
        collision_count(&a, &b, 8, 8);
    }

    #[test]
    #[should_panic(expected = "does not match K")]
    fn collision_count_rejects_wrong_k_for_width() {
        // Both rows agree with each other but not with K at this width.
        let a = vec![0u64; 2];
        let b = vec![0u64; 2];
        collision_count(&a, &b, 8, 8); // K=8 at b=8 needs 1 word, not 2
    }

    #[test]
    #[should_panic(expected = "packed rows differ in width")]
    fn batch_kernel_rejects_width_mismatch() {
        let arena = vec![0u64; 4];
        let q = vec![0u64; 2];
        bucket_collision_counts(&q, &arena, 1, &[0], 8, 8);
    }

    #[test]
    #[should_panic(expected = "out of arena bounds")]
    fn batch_kernel_rejects_out_of_bounds_slots() {
        let arena = vec![0u64; 2]; // room for slots 0..2 at wpr=1
        let q = vec![0u64; 1];
        bucket_collision_counts(&q, &arena, 1, &[2], 8, 8);
    }

    #[test]
    fn check_sketch_bits_accepts_exactly_the_supported_widths() {
        for b in SUPPORTED_BITS {
            check_sketch_bits(b).unwrap();
        }
        for b in [0u8, 3, 5, 6, 7, 9, 12, 24, 31, 33, 64] {
            assert!(check_sketch_bits(b).is_err(), "b={b}");
        }
    }

    #[test]
    fn random_pairs_property() {
        crate::util::testutil::property(10, |rng: &mut Rng| {
            let d = 512usize;
            let k = 256usize;
            let hasher = CMinHasher::new(d, k, rng.next_u64());
            let nnz = rng.range_usize(1, 60);
            let idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, d as u32)).collect();
            let full = hasher.sketch_sparse(&idx);
            for b in [1u8, 4, 8] {
                let sk = BBitSketch::compress(&full, b);
                assert_eq!(sk.estimate(&sk), 1.0);
                assert_eq!(sk.len(), k);
            }
        });
    }
}
