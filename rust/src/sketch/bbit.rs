//! b-bit sketching (Li & König, 2011) on top of C-MinHash — the
//! storage-side companion of the paper's permutation-side saving.
//!
//! Keeping only the lowest b bits of each hash shrinks sketches by
//! 32/b× at the cost of false collisions: two *different* hash values
//! collide on their low b bits with probability ≈ 1/2^b.  The standard
//! unbiased correction inverts that mixture:
//!
//! ```text
//! E[collision_b] ≈ J + (1 − J)/2^b    (D ≫ 2^b)
//! Ĵ_b = (collision_b − 1/2^b) / (1 − 1/2^b)
//! ```
//!
//! Combining both ideas: 2 permutations *and* b-bit sketches means a
//! similarity service at D = 2³⁰, K = 1024 stores 8 GB of permutations
//! → 8 KB, and 4 KB/item sketches → 128 B/item at b = 1.

use super::Sketcher;

/// A compressed sketch: K values of b bits each, bit-packed into u64
/// words.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BBitSketch {
    bits_per_hash: u8,
    k: usize,
    words: Vec<u64>,
}

impl BBitSketch {
    /// Compress a full sketch to b bits per hash (1 ≤ b ≤ 16).
    pub fn compress(full: &[u32], b: u8) -> Self {
        assert!((1..=16).contains(&b), "need 1 <= b <= 16");
        let k = full.len();
        let bits = b as usize;
        let mask = (1u64 << bits) - 1;
        let mut words = vec![0u64; (k * bits + 63) / 64];
        for (i, &h) in full.iter().enumerate() {
            let v = u64::from(h) & mask;
            let pos = i * bits;
            let (w, off) = (pos / 64, pos % 64);
            words[w] |= v << off;
            if off + bits > 64 {
                words[w + 1] |= v >> (64 - off);
            }
        }
        BBitSketch {
            bits_per_hash: b,
            k,
            words,
        }
    }

    /// Number of hash slots K.
    pub fn len(&self) -> usize {
        self.k
    }

    /// True iff K == 0.
    pub fn is_empty(&self) -> bool {
        self.k == 0
    }

    /// Bits kept per hash.
    pub fn bits_per_hash(&self) -> u8 {
        self.bits_per_hash
    }

    /// Storage footprint in bytes.
    pub fn size_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// The i-th b-bit value.
    #[inline]
    pub fn get(&self, i: usize) -> u64 {
        let bits = self.bits_per_hash as usize;
        let mask = (1u64 << bits) - 1;
        let pos = i * bits;
        let (w, off) = (pos / 64, pos % 64);
        let mut v = self.words[w] >> off;
        if off + bits > 64 {
            v |= self.words[w + 1] << (64 - off);
        }
        v & mask
    }

    /// Raw fraction of colliding b-bit slots.
    pub fn collision_fraction(&self, other: &BBitSketch) -> f64 {
        assert_eq!(self.k, other.k, "sketch lengths differ");
        assert_eq!(
            self.bits_per_hash, other.bits_per_hash,
            "bit widths differ"
        );
        let mut eq = 0usize;
        // Fast path for b dividing 64: word-level XOR + per-lane test.
        for i in 0..self.k {
            if self.get(i) == other.get(i) {
                eq += 1;
            }
        }
        eq as f64 / self.k as f64
    }

    /// Unbiased-corrected Jaccard estimate
    /// Ĵ_b = (c − 2^{−b}) / (1 − 2^{−b}), clamped to [0, 1].
    pub fn estimate(&self, other: &BBitSketch) -> f64 {
        let c = self.collision_fraction(other);
        let r = 1.0 / (1u64 << self.bits_per_hash) as f64;
        ((c - r) / (1.0 - r)).clamp(0.0, 1.0)
    }
}

/// A sketcher wrapper producing b-bit sketches directly.
pub struct BBitSketcher<S: Sketcher> {
    inner: S,
    b: u8,
}

impl<S: Sketcher> BBitSketcher<S> {
    /// Wrap a full-width sketcher.
    pub fn new(inner: S, b: u8) -> Self {
        assert!((1..=16).contains(&b));
        BBitSketcher { inner, b }
    }

    /// Sketch + compress in one call.
    pub fn sketch_sparse(&self, nonzeros: &[u32]) -> BBitSketch {
        BBitSketch::compress(&self.inner.sketch_sparse(nonzeros), self.b)
    }

    /// The wrapped sketcher.
    pub fn inner(&self) -> &S {
        &self.inner
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{CMinHasher, SparseVec};
    use crate::util::rng::Rng;

    #[test]
    fn pack_unpack_roundtrip() {
        let full: Vec<u32> = (0..100).map(|i| i * 37 % 1024).collect();
        for b in [1u8, 2, 3, 5, 8, 12, 16] {
            let sk = BBitSketch::compress(&full, b);
            assert_eq!(sk.len(), 100);
            let mask = (1u64 << b) - 1;
            for (i, &h) in full.iter().enumerate() {
                assert_eq!(sk.get(i), u64::from(h) & mask, "b={b} i={i}");
            }
        }
    }

    #[test]
    fn identical_sketches_estimate_one() {
        let full: Vec<u32> = (0..64).map(|i| i * 13).collect();
        let a = BBitSketch::compress(&full, 4);
        let b = BBitSketch::compress(&full, 4);
        assert_eq!(a.estimate(&b), 1.0);
    }

    #[test]
    fn compression_ratio() {
        let full: Vec<u32> = vec![0; 1024]; // 4 KB uncompressed
        let one_bit = BBitSketch::compress(&full, 1);
        assert_eq!(one_bit.size_bytes(), 128);
        let two_bit = BBitSketch::compress(&full, 2);
        assert_eq!(two_bit.size_bytes(), 256);
    }

    #[test]
    fn correction_recovers_jaccard_statistically() {
        // b-bit estimate must track exact J once corrected, for several b.
        let d = 4096usize;
        let k = 2048usize;
        let v = SparseVec::new(d as u32, (0..300).map(|i| i * 10).collect()).unwrap();
        let w =
            SparseVec::new(d as u32, (100..400).map(|i| i * 10).collect()).unwrap();
        let truth = v.jaccard(&w);
        for b in [1u8, 2, 4, 8] {
            let mut acc = 0.0;
            let reps = 12;
            for seed in 0..reps {
                let hasher = BBitSketcher::new(CMinHasher::new(d, k, seed), b);
                let sa = hasher.sketch_sparse(v.indices());
                let sb = hasher.sketch_sparse(w.indices());
                acc += sa.estimate(&sb);
            }
            let est = acc / reps as f64;
            // sd ≈ sqrt(Var_b / (K reps)); generous 0.05 tolerance
            assert!(
                (est - truth).abs() < 0.05,
                "b={b}: est {est} vs truth {truth}"
            );
        }
    }

    #[test]
    fn one_bit_raw_collision_is_biased_up() {
        // Without the correction, 1-bit collisions overshoot J by
        // ≈ (1−J)/2 — the reason the correction exists.
        let d = 2048usize;
        let hasher = CMinHasher::new(d, 2048, 3);
        let v: Vec<u32> = (0..200).map(|i| i * 10).collect();
        let w: Vec<u32> = (1000..1200).map(|i| i as u32).collect(); // disjoint-ish
        let a = BBitSketch::compress(&hasher.sketch_sparse(&v), 1);
        let b = BBitSketch::compress(&hasher.sketch_sparse(&w), 1);
        let raw = a.collision_fraction(&b);
        assert!(raw > 0.3, "raw 1-bit collisions should be ~0.5, got {raw}");
        assert!(a.estimate(&b) < 0.15, "corrected estimate near 0");
    }

    #[test]
    fn random_pairs_property() {
        crate::util::testutil::property(10, |rng: &mut Rng| {
            let d = 512usize;
            let k = 256usize;
            let hasher = CMinHasher::new(d, k, rng.next_u64());
            let nnz = rng.range_usize(1, 60);
            let idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, d as u32)).collect();
            let full = hasher.sketch_sparse(&idx);
            for b in [1u8, 4, 8] {
                let sk = BBitSketch::compress(&full, b);
                assert_eq!(sk.estimate(&sk), 1.0);
                assert_eq!(sk.len(), k);
            }
        });
    }
}
