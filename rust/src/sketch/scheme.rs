//! The pluggable sketch-scheme registry: one name for every hasher the
//! crate ships, parsed from configs/CLI, threaded through the
//! coordinator, stamped into snapshots, and reported by `stats`.
//!
//! Dispatch is by enum (not a user-extensible trait registry): the set
//! of schemes is closed by construction — each one is backed by paper
//! math and a consistency suite — and enum dispatch keeps scheme
//! selection exhaustively matchable everywhere it is consumed
//! (coordinator, snapshot codec, benches, docs tables).

use super::{
    CMinHasher, ClassicMinHasher, CophHasher, IuhHasher, OphHasher, Sketcher,
    ZeroPiHasher,
};
use std::fmt;
use std::sync::Arc;

/// Which minwise-hashing scheme the service sketches with.
///
/// All six produce length-K sketches over `0..D` (sentinel `D` for the
/// all-zero vector) scored by the same collision estimator
/// ([`super::estimate`]), but they differ in permutation memory and
/// sketch cost — see `docs/SCHEMES.md` for the full comparison table.
///
/// ```
/// use cminhash::sketch::{SketchScheme, Sketcher};
/// let s = SketchScheme::parse("coph").unwrap();
/// assert_eq!(s, SketchScheme::Coph);
/// let h = s.build(64, 16, 42).unwrap();          // D, K, seed
/// assert_eq!(h.sketch_sparse(&[1, 5, 40]).len(), 16);
/// assert!(SketchScheme::parse("md5").is_err());
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SketchScheme {
    /// Classical MinHash: K independent permutations, O(K·D) memory,
    /// O(f·K) per sketch (Algorithm 1 — the baseline).
    Classic,
    /// C-MinHash-(σ, π): two permutations, O(D) memory, O(f·K) per
    /// sketch (Algorithm 3 — the source paper's recommendation, and
    /// the default).
    Cmh,
    /// C-MinHash-(0, π): one permutation, no initial σ scramble
    /// (Algorithm 2 — the ablation; arXiv:2109.04595 studies dropping
    /// σ in practice).
    ZeroPi,
    /// One Permutation Hashing with optimal densification: one
    /// permutation, O(D) memory, **O(f)** per sketch.
    Oph,
    /// C-OPH (arXiv:2111.09544): OPH where the in-bin ordering is one
    /// circulant length-D/K permutation (plus the σ scatter, so O(D)
    /// total like `oph`), **O(f)** per sketch.
    Coph,
    /// Iterative universal hashing (arXiv:1401.6124): K keyed
    /// bijections generated from **O(1)** state — no permutation
    /// tables at all — each key derived from the previous by one
    /// modular addition.  O(f·K) per sketch.
    Iuh,
}

impl SketchScheme {
    /// Every scheme, in documentation/bench order.
    pub const ALL: [SketchScheme; 6] = [
        SketchScheme::Classic,
        SketchScheme::Cmh,
        SketchScheme::ZeroPi,
        SketchScheme::Oph,
        SketchScheme::Coph,
        SketchScheme::Iuh,
    ];

    /// Parse a scheme name: `classic | cmh | zero-pi | oph | coph | iuh`.
    pub fn parse(s: &str) -> crate::Result<Self> {
        Ok(match s {
            "classic" => SketchScheme::Classic,
            "cmh" => SketchScheme::Cmh,
            "zero-pi" => SketchScheme::ZeroPi,
            "oph" => SketchScheme::Oph,
            "coph" => SketchScheme::Coph,
            "iuh" => SketchScheme::Iuh,
            other => {
                return Err(crate::Error::Invalid(format!(
                    "unknown sketch scheme {other:?} \
                     (classic|cmh|zero-pi|oph|coph|iuh)"
                )))
            }
        })
    }

    /// Canonical name (the `parse` spelling).
    pub fn as_str(self) -> &'static str {
        match self {
            SketchScheme::Classic => "classic",
            SketchScheme::Cmh => "cmh",
            SketchScheme::ZeroPi => "zero-pi",
            SketchScheme::Oph => "oph",
            SketchScheme::Coph => "coph",
            SketchScheme::Iuh => "iuh",
        }
    }

    /// Stable on-disk code used by the snapshot header (never reuse or
    /// renumber — snapshots outlive binaries).
    pub fn code(self) -> u32 {
        match self {
            SketchScheme::Classic => 1,
            SketchScheme::Cmh => 2,
            SketchScheme::ZeroPi => 3,
            SketchScheme::Oph => 4,
            SketchScheme::Coph => 5,
            SketchScheme::Iuh => 6,
        }
    }

    /// Decode a snapshot-header code.
    pub fn from_code(code: u32) -> crate::Result<Self> {
        Ok(match code {
            1 => SketchScheme::Classic,
            2 => SketchScheme::Cmh,
            3 => SketchScheme::ZeroPi,
            4 => SketchScheme::Oph,
            5 => SketchScheme::Coph,
            6 => SketchScheme::Iuh,
            other => {
                return Err(crate::Error::Invalid(format!(
                    "unknown sketch-scheme code {other} \
                     (snapshot from a newer build?)"
                )))
            }
        })
    }

    /// Validate a (D, K) shape for this scheme without building it:
    /// every scheme needs `1 <= K <= D`; the OPH family additionally
    /// needs `K | D` so bins are equal-width (delegated to the one
    /// authority in the `oph` module, so the config/CLI path and the
    /// hasher constructors give the same diagnostic).
    pub fn validate(self, d: usize, k: usize) -> crate::Result<()> {
        if k == 0 || k > d {
            return Err(crate::Error::Invalid(format!(
                "need 1 <= K <= D, got K={k}, D={d}"
            )));
        }
        if matches!(self, SketchScheme::Oph | SketchScheme::Coph) {
            super::oph::check_bins(d, k)?;
        }
        Ok(())
    }

    /// Construct the scheme's hasher for `(D, K, seed)`.  For a fixed
    /// `(scheme, D, K, seed)` the hasher — and therefore every sketch —
    /// is deterministic, which is what makes sketches interchangeable
    /// between offline jobs and the server.
    pub fn build(
        self,
        d: usize,
        k: usize,
        seed: u64,
    ) -> crate::Result<Arc<dyn Sketcher>> {
        self.validate(d, k)?;
        Ok(match self {
            SketchScheme::Classic => Arc::new(ClassicMinHasher::new(d, k, seed)),
            SketchScheme::Cmh => Arc::new(CMinHasher::new(d, k, seed)),
            SketchScheme::ZeroPi => Arc::new(ZeroPiHasher::new(d, k, seed)),
            SketchScheme::Oph => Arc::new(OphHasher::new(d, k, seed)?),
            SketchScheme::Coph => Arc::new(CophHasher::new(d, k, seed)?),
            SketchScheme::Iuh => Arc::new(IuhHasher::new(d, k, seed)),
        })
    }
}

impl fmt::Display for SketchScheme {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_every_scheme() {
        for s in SketchScheme::ALL {
            assert_eq!(SketchScheme::parse(s.as_str()).unwrap(), s);
            assert_eq!(SketchScheme::from_code(s.code()).unwrap(), s);
            assert_eq!(format!("{s}"), s.as_str());
        }
        assert!(SketchScheme::parse("sha256").is_err());
        assert!(SketchScheme::from_code(0).is_err());
        assert!(SketchScheme::from_code(99).is_err());
    }

    #[test]
    fn codes_are_unique_and_stable() {
        let codes: Vec<u32> = SketchScheme::ALL.iter().map(|s| s.code()).collect();
        assert_eq!(codes, vec![1, 2, 3, 4, 5, 6], "codes are an on-disk format");
    }

    #[test]
    fn validate_gates_the_oph_family_on_divisibility() {
        for s in SketchScheme::ALL {
            assert!(s.validate(64, 0).is_err());
            assert!(s.validate(64, 65).is_err());
            assert!(s.validate(64, 16).is_ok());
        }
        assert!(SketchScheme::Cmh.validate(64, 48).is_ok());
        assert!(SketchScheme::Oph.validate(64, 48).is_err());
        assert!(SketchScheme::Coph.validate(64, 48).is_err());
    }

    #[test]
    fn build_produces_working_hashers_with_shared_conventions() {
        let nz: Vec<u32> = vec![3, 17, 40, 63];
        for s in SketchScheme::ALL {
            let h = s.build(64, 16, 7).unwrap();
            assert_eq!(h.dim(), 64);
            assert_eq!(h.num_hashes(), 16);
            let sk = h.sketch_sparse(&nz);
            assert_eq!(sk.len(), 16);
            assert!(sk.iter().all(|&v| v <= 64), "{s}: values in 0..=D");
            assert_eq!(sk, h.sketch_sparse(&nz), "{s}: deterministic");
            // shared empty-vector sentinel convention
            assert!(
                h.sketch_sparse(&[]).iter().all(|&v| v == 64),
                "{s}: sentinel"
            );
        }
        assert!(SketchScheme::Oph.build(64, 48, 7).is_err());
    }
}
