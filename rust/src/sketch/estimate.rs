//! Jaccard estimation from sketches (eqs. 2/4/7) and the error metrics
//! the paper's evaluation reports (MAE for Fig. 7, MSE for Fig. 6).

use super::{SparseVec, Sketcher};

/// Collision-fraction estimator Ĵ = (1/K) Σ 1{h_k(v) = h_k(w)}.
///
/// Both sketches must come from the *same* hasher (same permutations).
///
/// ```
/// use cminhash::sketch::estimate;
/// assert_eq!(estimate(&[1, 2, 3, 4], &[1, 2, 9, 9]), 0.5);
/// assert_eq!(estimate(&[7, 7], &[7, 7]), 1.0);
/// ```
#[inline]
pub fn estimate(hv: &[u32], hw: &[u32]) -> f64 {
    assert_eq!(hv.len(), hw.len(), "sketch lengths differ");
    assert!(!hv.is_empty(), "empty sketches");
    let collisions = hv.iter().zip(hw).filter(|(a, b)| a == b).count();
    collisions as f64 / hv.len() as f64
}

/// Mean absolute error of estimates against exact Jaccard over
/// explicit pairs.
pub fn mean_absolute_error(estimates: &[f64], truths: &[f64]) -> f64 {
    assert_eq!(estimates.len(), truths.len());
    assert!(!estimates.is_empty());
    estimates
        .iter()
        .zip(truths)
        .map(|(e, t)| (e - t).abs())
        .sum::<f64>()
        / estimates.len() as f64
}

/// Mean squared error (variance + bias², the Fig. 6 metric).
pub fn mean_squared_error(estimates: &[f64], truth: f64) -> f64 {
    assert!(!estimates.is_empty());
    estimates
        .iter()
        .map(|e| (e - truth) * (e - truth))
        .sum::<f64>()
        / estimates.len() as f64
}

/// All-pairs MAE of a sketcher over a dataset — the exact protocol of
/// the paper's §4.2: estimate J for all n(n−1)/2 pairs and average the
/// absolute errors against exact Jaccard.
pub fn estimate_batch_mae(sketcher: &dyn Sketcher, rows: &[SparseVec]) -> f64 {
    let sketches: Vec<Vec<u32>> = rows
        .iter()
        .map(|r| sketcher.sketch_sparse(r.indices()))
        .collect();
    let mut err = 0.0f64;
    let mut pairs = 0usize;
    for i in 0..rows.len() {
        for j in (i + 1)..rows.len() {
            let est = estimate(&sketches[i], &sketches[j]);
            let truth = rows[i].jaccard(&rows[j]);
            err += (est - truth).abs();
            pairs += 1;
        }
    }
    if pairs == 0 {
        0.0
    } else {
        err / pairs as f64
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::sketch::CMinHasher;

    #[test]
    fn identical_sketches_estimate_one() {
        let h = vec![1u32, 5, 9];
        assert_eq!(estimate(&h, &h), 1.0);
    }

    #[test]
    fn disjoint_sketches_estimate_zero() {
        assert_eq!(estimate(&[1, 2, 3], &[4, 5, 6]), 0.0);
    }

    #[test]
    fn partial_collision_fraction() {
        assert!((estimate(&[1, 2, 3, 4], &[1, 2, 9, 9]) - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "lengths differ")]
    fn mismatched_lengths_panic() {
        estimate(&[1], &[1, 2]);
    }

    #[test]
    fn mae_and_mse_basics() {
        assert!((mean_absolute_error(&[0.5, 0.7], &[0.4, 0.9]) - 0.15).abs() < 1e-12);
        assert!((mean_squared_error(&[0.4, 0.6], 0.5) - 0.01).abs() < 1e-12);
    }

    #[test]
    fn batch_mae_is_small_for_large_k() {
        // With K = D the circulant sketch is highly informative; the MAE
        // over a few structured pairs must be far below a coin flip.
        let d = 256;
        let h = CMinHasher::new(d, 256, 7);
        let rows: Vec<SparseVec> = (0..6u32)
            .map(|i| {
                SparseVec::new(d as u32, (i * 10..i * 10 + 40).collect()).unwrap()
            })
            .collect();
        let mae = estimate_batch_mae(&h, &rows);
        assert!(mae < 0.1, "mae={mae}");
    }

    #[test]
    fn estimator_tracks_true_jaccard() {
        // The shared structured-pair generator is the one corpus all
        // statistical gates (tests *and* benches) measure against.
        let d = 512;
        let h = CMinHasher::new(d, 512, 3);
        let (v, w, truth) =
            crate::util::testutil::overlap_pair(d as u32, 64, 64, 32); // J = 1/3
        assert_eq!(truth, v.jaccard(&w));
        let est = estimate(
            &h.sketch_sparse(v.indices()),
            &h.sketch_sparse(w.indices()),
        );
        assert!((est - truth).abs() < 0.12, "est={est} truth={truth}");
    }
}
