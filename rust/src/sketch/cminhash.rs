//! C-MinHash hashers (Algorithms 2 and 3) — the paper's contribution.
//!
//! The hot loop exploits the circulant structure: with the doubled array
//! `pi2 = π ‖ π`, the k-th hash (k = 1..K) of a sparse vector with
//! nonzero set S is
//!
//! ```text
//! h_k = min_{s ∈ S} π[(s - k) mod D] = min_{s ∈ S} pi2[s + D - k]
//! ```
//!
//! so for each nonzero `s` the K values live in the *contiguous,
//! reversed* slice `pi2[s + D - K .. s + D]` — one streaming pass per
//! nonzero, O(f·K) time, O(D) memory, zero modular arithmetic.  This is
//! the CPU mirror of the Pallas kernel's window trick (DESIGN.md
//! §Hardware-Adaptation).

use super::perm::{Perm, Role};
use super::Sketcher;

/// C-MinHash-(σ, π) — Algorithm 3, the paper's recommended scheme.
///
/// Stores exactly two permutations regardless of K (the paper's memory
/// pitch): σ as its *inverse* (so sparse gathers are O(f)) and π doubled.
///
/// ```
/// use cminhash::sketch::{estimate, CMinHasher, Sketcher};
/// let h = CMinHasher::new(1024, 128, 42);          // D, K, seed
/// let hv = h.sketch_sparse(&[3, 17, 900]);         // sorted nonzeros
/// let hw = h.sketch_sparse(&[3, 17, 901]);
/// assert_eq!(hv.len(), 128);
/// let jhat = estimate(&hv, &hw);                   // true J = 2/4
/// assert!(jhat > 0.0 && jhat < 1.0);
/// ```
#[derive(Clone, Debug)]
pub struct CMinHasher {
    d: usize,
    k: usize,
    /// inv_sigma[s] = i such that sigma[i] = s; v'[i] = v[sigma[i]]
    /// means nonzero s of v lands at position inv_sigma[s] of v'.
    inv_sigma: Vec<u32>,
    /// π ‖ π.
    pi2: Vec<u32>,
}

impl CMinHasher {
    /// Seeded constructor (σ and π drawn on independent streams).
    // `Perm::generate` always yields a valid permutation of 0..d.
    #[allow(clippy::disallowed_methods)]
    pub fn new(d: usize, k: usize, seed: u64) -> Self {
        let sigma = Perm::generate(d, seed, Role::Sigma);
        let pi = Perm::generate(d, seed, Role::Pi);
        Self::from_perms(k, &sigma, &pi).expect("generated perms are valid")
    }

    /// Explicit permutations (must both be length D; requires K ≤ D).
    pub fn from_perms(k: usize, sigma: &Perm, pi: &Perm) -> crate::Result<Self> {
        let d = sigma.len();
        if pi.len() != d {
            return Err(crate::Error::Invalid(format!(
                "sigma has D={d} but pi has D={}",
                pi.len()
            )));
        }
        if k == 0 || k > d {
            return Err(crate::Error::Invalid(format!(
                "need 1 <= K <= D, got K={k}, D={d}"
            )));
        }
        Ok(CMinHasher {
            d,
            k,
            inv_sigma: sigma.inverse().values().to_vec(),
            pi2: pi.doubled(),
        })
    }

    /// The σ-permuted nonzero set of a sparse vector.
    fn permuted(&self, nonzeros: &[u32]) -> Vec<u32> {
        nonzeros
            .iter()
            .map(|&s| self.inv_sigma[s as usize])
            .collect()
    }
}

impl Sketcher for CMinHasher {
    fn dim(&self) -> usize {
        self.d
    }

    fn num_hashes(&self) -> usize {
        self.k
    }

    fn sketch_sparse(&self, nonzeros: &[u32]) -> Vec<u32> {
        let permuted = self.permuted(nonzeros);
        circulant_min(&self.pi2, self.d, self.k, &permuted)
    }
}

/// C-MinHash-(0, π) — Algorithm 2, the no-σ ablation.  Kept as a public
/// type because Figure 6/7 compare it directly and downstream users may
/// want it when their data is already "structureless".
#[derive(Clone, Debug)]
pub struct ZeroPiHasher {
    d: usize,
    k: usize,
    pi2: Vec<u32>,
}

impl ZeroPiHasher {
    /// Seeded constructor (same π stream as [`CMinHasher`] for the same
    /// seed, so ablations are paired).
    // `Perm::generate` always yields a valid permutation of 0..d.
    #[allow(clippy::disallowed_methods)]
    pub fn new(d: usize, k: usize, seed: u64) -> Self {
        let pi = Perm::generate(d, seed, Role::Pi);
        Self::from_perm(k, &pi).expect("generated perm is valid")
    }

    /// Explicit π (requires K ≤ D).
    pub fn from_perm(k: usize, pi: &Perm) -> crate::Result<Self> {
        let d = pi.len();
        if k == 0 || k > d {
            return Err(crate::Error::Invalid(format!(
                "need 1 <= K <= D, got K={k}, D={d}"
            )));
        }
        Ok(ZeroPiHasher {
            d,
            k,
            pi2: pi.doubled(),
        })
    }
}

impl Sketcher for ZeroPiHasher {
    fn dim(&self) -> usize {
        self.d
    }

    fn num_hashes(&self) -> usize {
        self.k
    }

    fn sketch_sparse(&self, nonzeros: &[u32]) -> Vec<u32> {
        circulant_min(&self.pi2, self.d, self.k, nonzeros)
    }
}

/// Shared hot loop: `out[k-1] = min_{s ∈ S} pi2[s + D - k]`, k = 1..K.
///
/// Per nonzero `s` the needed permutation entries are the contiguous
/// window `pi2[s + d - k .. s + d]`.  The accumulator is kept in
/// *window order* (i.e. reversed hash order) so the inner loop is a
/// straight elementwise `min` over two forward slices — which LLVM
/// autovectorizes to packed `pminud`-style SIMD — and reversed once at
/// the end.  (§Perf: 2.6× over the reverse-zip formulation.)
#[inline]
pub(crate) fn circulant_min(pi2: &[u32], d: usize, k: usize, nonzeros: &[u32]) -> Vec<u32> {
    // acc[j] accumulates out[k - 1 - j].
    let mut acc = vec![d as u32; k];
    for &s in nonzeros {
        let s = s as usize;
        debug_assert!(s < d);
        let window = &pi2[s + d - k..s + d];
        for (o, &w) in acc.iter_mut().zip(window.iter()) {
            *o = (*o).min(w);
        }
    }
    acc.reverse();
    acc
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    /// Literal transcription of Algorithm 2 used as a local oracle.
    fn naive_0pi(pi: &Perm, d: usize, k: usize, nz: &[u32]) -> Vec<u32> {
        (1..=k as i64)
            .map(|kk| {
                nz.iter()
                    .map(|&s| {
                        let idx = ((s as i64 - kk) % d as i64 + d as i64) % d as i64;
                        pi.at(idx as usize)
                    })
                    .min()
                    .unwrap_or(d as u32)
            })
            .collect()
    }

    #[test]
    fn matches_naive_modular_version() {
        let d = 37;
        let pi = Perm::generate(d, 5, Role::Pi);
        let h = ZeroPiHasher::from_perm(17, &pi).unwrap();
        for nz in [vec![], vec![0], vec![36], vec![1, 5, 8, 30, 36]] {
            assert_eq!(h.sketch_sparse(&nz), naive_0pi(&pi, d, 17, &nz));
        }
    }

    #[test]
    fn sigma_pi_equals_zero_pi_on_permuted_input() {
        let d = 64;
        let sigma = Perm::generate(d, 11, Role::Sigma);
        let pi = Perm::generate(d, 11, Role::Pi);
        let cm = CMinHasher::from_perms(32, &sigma, &pi).unwrap();
        let zp = ZeroPiHasher::from_perm(32, &pi).unwrap();
        let nz = vec![2u32, 17, 40, 63];
        // v'[i] = v[sigma[i]] -> nonzeros map through inv_sigma.
        let inv = sigma.inverse();
        let mut permuted: Vec<u32> = nz.iter().map(|&s| inv.at(s as usize)).collect();
        permuted.sort_unstable();
        assert_eq!(cm.sketch_sparse(&nz), zp.sketch_sparse(&permuted));
    }

    #[test]
    fn identity_sigma_is_noop() {
        let d = 48;
        let pi = Perm::generate(d, 3, Role::Pi);
        let cm = CMinHasher::from_perms(24, &Perm::identity(d), &pi).unwrap();
        let zp = ZeroPiHasher::from_perm(24, &pi).unwrap();
        let nz = vec![0u32, 9, 30];
        assert_eq!(cm.sketch_sparse(&nz), zp.sketch_sparse(&nz));
    }

    #[test]
    fn k_bounds_enforced() {
        let pi = Perm::generate(8, 0, Role::Pi);
        assert!(ZeroPiHasher::from_perm(0, &pi).is_err());
        assert!(ZeroPiHasher::from_perm(9, &pi).is_err());
        assert!(ZeroPiHasher::from_perm(8, &pi).is_ok());
    }

    #[test]
    fn full_vector_hashes_to_zero() {
        let d = 40;
        let h = CMinHasher::new(d, 40, 2);
        let all: Vec<u32> = (0..d as u32).collect();
        assert!(h.sketch_sparse(&all).iter().all(|&v| v == 0));
    }
}
