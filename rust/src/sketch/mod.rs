//! Pure-Rust sketching: six pluggable minwise-hashing schemes plus
//! estimators.
//!
//! The schemes — selected end to end via [`SketchScheme`] — are
//! classical MinHash ([`ClassicMinHasher`]), the source paper's
//! C-MinHash-(σ, π) ([`CMinHasher`]) and C-MinHash-(0, π)
//! ([`ZeroPiHasher`]), One Permutation Hashing with optimal
//! densification ([`OphHasher`]), circulant OPH ([`CophHasher`]), and
//! O(1)-state iterative universal hashing ([`IuhHasher`]);
//! `docs/SCHEMES.md` compares them.
//!
//! These implementations are the CPU fallback engine of the server, the
//! baseline for every benchmark, and the oracle for property tests.
//! They follow the exact conventions of `python/compile/kernels/ref.py`
//! (verified bit-for-bit by `rust/tests/golden.rs` against oracle
//! vectors exported at `make artifacts` time):
//!
//! * permutations are 0-indexed value arrays (`pi[i]` ∈ `0..D`);
//! * the k-th C-MinHash hash (k = 1..K) uses `pi[(i - k) mod D]`
//!   (right-circulant shift by k, Algorithm 2/3);
//! * `sigma` is applied as a gather `v'[i] = v[sigma[i]]`;
//! * an all-zero vector hashes to the sentinel `D` in every slot —
//!   in every scheme, so estimators and the b-bit compressor never
//!   need to know which hasher produced a sketch.

mod bbit;
mod cminhash;
mod estimate;
mod iuh;
mod minhash;
mod oph;
mod perm;
mod scheme;
mod sparse;

pub use bbit::{
    bucket_collision_counts, check_sketch_bits, collision_count, corrected_estimate,
    pack_row, packed_words, unpack_row, BBitSketch, BBitSketcher, SUPPORTED_BITS,
};
pub use cminhash::{CMinHasher, ZeroPiHasher};
pub use estimate::{estimate, estimate_batch_mae, mean_absolute_error, mean_squared_error};
pub use iuh::IuhHasher;
pub use minhash::ClassicMinHasher;
pub use oph::{CophHasher, OphHasher};
pub use perm::{Perm, Role};
pub use scheme::SketchScheme;
pub use sparse::SparseVec;

/// Common interface for all sketchers: D-dimensional binary vectors in,
/// K hash values out.
///
/// Implementations are interchangeable downstream (store, index,
/// estimator) because they share the value range `0..D` with sentinel
/// `D`; construct one directly or via [`SketchScheme::build`].
///
/// ```
/// use cminhash::sketch::{SketchScheme, Sketcher};
/// let h = SketchScheme::Oph.build(32, 8, 1).unwrap();
/// let dense: Vec<u8> = (0..32).map(|i| u8::from(i % 3 == 0)).collect();
/// // dense and sparse entry points agree by construction
/// let nz: Vec<u32> = (0..32).filter(|i| i % 3 == 0).collect();
/// assert_eq!(h.sketch_dense(&dense), h.sketch_sparse(&nz));
/// ```
pub trait Sketcher: Send + Sync {
    /// Data dimensionality D.
    fn dim(&self) -> usize;
    /// Number of hashes K.
    fn num_hashes(&self) -> usize;
    /// Sketch a sparse vector given its sorted nonzero indices.
    fn sketch_sparse(&self, nonzeros: &[u32]) -> Vec<u32>;

    /// Sketch a dense 0/1 row.
    fn sketch_dense(&self, bits: &[u8]) -> Vec<u32> {
        debug_assert_eq!(bits.len(), self.dim());
        let nz: Vec<u32> = bits
            .iter()
            .enumerate()
            .filter(|(_, &b)| b != 0)
            .map(|(i, _)| i as u32)
            .collect();
        self.sketch_sparse(&nz)
    }

    /// Sketch a batch of sparse vectors.
    fn sketch_batch(&self, rows: &[Vec<u32>]) -> Vec<Vec<u32>> {
        rows.iter().map(|r| self.sketch_sparse(r)).collect()
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn dense_and_sparse_agree() {
        let h = CMinHasher::new(64, 32, 7);
        let mut bits = vec![0u8; 64];
        for i in [3usize, 17, 40, 63] {
            bits[i] = 1;
        }
        let nz: Vec<u32> = vec![3, 17, 40, 63];
        assert_eq!(h.sketch_dense(&bits), h.sketch_sparse(&nz));
    }

    #[test]
    fn empty_vector_gets_sentinel() {
        for sk in [
            Box::new(CMinHasher::new(32, 16, 1)) as Box<dyn Sketcher>,
            Box::new(ZeroPiHasher::new(32, 16, 1)),
            Box::new(ClassicMinHasher::new(32, 16, 1)),
            Box::new(OphHasher::new(32, 16, 1).unwrap()),
            Box::new(CophHasher::new(32, 16, 1).unwrap()),
            Box::new(IuhHasher::new(32, 16, 1)),
        ] {
            let h = sk.sketch_sparse(&[]);
            assert!(h.iter().all(|&v| v == 32), "sentinel expected");
        }
    }
}
