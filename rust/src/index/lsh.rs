//! Banding LSH over MinHash-style sketches.
//!
//! A sketch of K values is split into `bands` bands of `rows_per_band`
//! values; each band is hashed into a table.  Two items collide in a
//! band with probability J^r, and in at least one band with probability
//! 1 − (1 − J^r)^b — the classic S-curve.  Candidates are re-ranked by
//! the full-sketch collision estimate.
//!
//! The index has two storage modes, selected by the sketch width:
//!
//! * **full** (`bits = 32`) — one `Vec<u32>` row per item, candidates
//!   re-ranked by [`estimate`]; bit-for-bit the pre-b-bit behavior.
//! * **packed** (`bits < 32`) — rows live in one contiguous
//!   [`PackedRows`] bit-matrix (K·b bits per item), band signatures
//!   hash the packed band bits directly (no unpacking), postings hold
//!   arena *slots* instead of ids so the scoring loop reads candidate
//!   rows sequentially, and candidates are scored by the word-level
//!   XOR + popcount kernel fed through the unbiased b-bit correction.

use crate::index::packed::PackedRows;
use crate::obs::{stage, Stage};
use crate::sketch::{
    bucket_collision_counts, check_sketch_bits, corrected_estimate, estimate,
    pack_row, packed_words,
};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};

/// Band configuration.  `bands * rows_per_band` must be ≤ K.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IndexConfig {
    /// Number of bands b.
    pub bands: usize,
    /// Rows per band r.
    pub rows_per_band: usize,
}

impl IndexConfig {
    /// The probability that a pair with Jaccard `j` becomes a candidate:
    /// 1 − (1 − j^r)^b.
    pub fn candidate_probability(&self, j: f64) -> f64 {
        1.0 - (1.0 - j.powi(self.rows_per_band as i32)).powi(self.bands as i32)
    }

    /// The similarity threshold where the S-curve is steepest,
    /// ≈ (1/b)^(1/r).
    pub fn threshold(&self) -> f64 {
        (1.0 / self.bands as f64).powf(1.0 / self.rows_per_band as f64)
    }
}

/// A scored neighbor returned by queries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    /// Item id (as assigned at insert time).
    pub id: u64,
    /// Full-sketch collision estimate Ĵ (b-bit corrected in packed
    /// storage mode).
    pub score: f64,
}

/// Sort neighbors into the one total result order every query path
/// shares — score descending, then id ascending.  The sharded store
/// merges per-shard results with this same function, which is what
/// makes sharding a pure scaling knob (N = 1 byte-identical to the
/// bare index, N > 1 merged deterministically).
pub fn sort_neighbors(xs: &mut [Neighbor]) {
    xs.sort_by(|x, y| y.score.total_cmp(&x.score).then(x.id.cmp(&y.id)));
}

/// Row storage: full-width `u32` rows or the packed bit-matrix.
#[derive(Debug)]
enum Rows {
    Full(HashMap<u64, Vec<u32>>),
    Packed(PackedRows),
}

/// The banding index: b hash tables over band signatures, plus the
/// stored sketches for re-ranking.
///
/// Posting-list values are item **ids** in full mode and arena
/// **slots** in packed mode (translated back to ids at the query
/// boundary), so deletions must erase postings before the slot is
/// recycled — which [`BandingIndex::remove`] does.
#[derive(Debug)]
pub struct BandingIndex {
    cfg: IndexConfig,
    k: usize,
    bits: u8,
    tables: Vec<HashMap<u64, Vec<u64>>>,
    rows: Rows,
    /// Candidates collected (post-dedup) across this index's lifetime —
    /// an atomic so read-locked query paths can count.
    candidates: AtomicU64,
}

/// FNV-1a over a band's u32 values — cheap, deterministic, dependency
/// free.
#[inline]
fn band_hash(values: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in values {
        for b in v.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// FNV-1a over a band's packed bit range — the packed-mode band
/// signature, computed without unpacking lanes: the `nbits` bits from
/// `start_bit` are streamed out of the word array in ≤ 64-bit chunks.
/// Equal band values imply equal bits imply equal signatures.
#[inline]
fn band_hash_packed(words: &[u64], start_bit: usize, nbits: usize) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut pos = start_bit;
    let mut left = nbits;
    while left > 0 {
        let take = left.min(64);
        let (w, off) = (pos / 64, pos % 64);
        let mut v = words[w] >> off;
        if off > 0 && off + take > 64 {
            v |= words[w + 1] << (64 - off);
        }
        if take < 64 {
            v &= (1u64 << take) - 1;
        }
        for b in v.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
        pos += take;
        left -= take;
    }
    h
}

/// All band signatures of one packed row — the one definition insert,
/// remove, and the query path share, so their postings can never
/// desynchronize.
fn packed_band_sigs(words: &[u64], bands: usize, band_bits: usize) -> Vec<u64> {
    (0..bands)
        .map(|b| band_hash_packed(words, b * band_bits, band_bits))
        .collect()
}

impl BandingIndex {
    /// Create a full-width index over sketches of length `k`
    /// (equivalent to [`BandingIndex::with_bits`] at `bits = 32`).
    pub fn new(k: usize, cfg: IndexConfig) -> crate::Result<Self> {
        Self::with_bits(k, cfg, 32)
    }

    /// Create an index over sketches of length `k` storing `bits` bits
    /// per hash — 32 keeps full-width rows, anything smaller packs
    /// rows into the contiguous bit-matrix and scores queries with the
    /// popcount kernel.
    pub fn with_bits(k: usize, cfg: IndexConfig, bits: u8) -> crate::Result<Self> {
        check_sketch_bits(bits)?;
        if cfg.bands == 0 || cfg.rows_per_band == 0 {
            return Err(crate::Error::Invalid("bands and rows must be > 0".into()));
        }
        if cfg.bands * cfg.rows_per_band > k {
            return Err(crate::Error::Invalid(format!(
                "bands({}) * rows({}) > K({k})",
                cfg.bands, cfg.rows_per_band
            )));
        }
        let rows = if bits == 32 {
            Rows::Full(HashMap::new())
        } else {
            Rows::Packed(PackedRows::new(k, bits))
        };
        Ok(BandingIndex {
            cfg,
            k,
            bits,
            tables: vec![HashMap::new(); cfg.bands],
            rows,
            candidates: AtomicU64::new(0),
        })
    }

    /// Configuration.
    pub fn config(&self) -> IndexConfig {
        self.cfg
    }

    /// Bits stored per hash (32 = full width).
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Resident bytes per stored sketch row.
    pub fn sketch_bytes_per_item(&self) -> usize {
        match &self.rows {
            Rows::Full(_) => self.k * 4,
            Rows::Packed(_) => packed_words(self.k, self.bits) * 8,
        }
    }

    /// Number of indexed items.
    pub fn len(&self) -> usize {
        match &self.rows {
            Rows::Full(map) => map.len(),
            Rows::Packed(rows) => rows.len(),
        }
    }

    /// True iff no items are indexed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The packed band signatures of one packed row.
    fn packed_sigs(&self, words: &[u64]) -> Vec<u64> {
        packed_band_sigs(
            words,
            self.cfg.bands,
            self.cfg.rows_per_band * self.bits as usize,
        )
    }

    /// Insert an item's sketch under `id` (ids are expected unique,
    /// enforced here).
    pub fn insert(&mut self, id: u64, sketch: &[u32]) -> crate::Result<()> {
        if sketch.len() != self.k {
            return Err(crate::Error::ShapeMismatch {
                what: "sketch",
                expected: self.k,
                got: sketch.len(),
            });
        }
        let r = self.cfg.rows_per_band;
        match &mut self.rows {
            Rows::Full(map) => {
                if map.contains_key(&id) {
                    return Err(crate::Error::Invalid(format!("duplicate id {id}")));
                }
                for (b, table) in self.tables.iter_mut().enumerate() {
                    let sig = band_hash(&sketch[b * r..(b + 1) * r]);
                    table.entry(sig).or_default().push(id);
                }
                map.insert(id, sketch.to_vec());
            }
            Rows::Packed(rows) => {
                if rows.contains(id) {
                    return Err(crate::Error::Invalid(format!("duplicate id {id}")));
                }
                let slot = rows.insert(id, sketch);
                let sigs = packed_band_sigs(
                    rows.row(slot),
                    self.cfg.bands,
                    r * self.bits as usize,
                );
                for (table, sig) in self.tables.iter_mut().zip(sigs) {
                    table.entry(sig).or_default().push(slot as u64);
                }
            }
        }
        Ok(())
    }

    /// Insert an item's *already-packed* row under `id` — the binary
    /// wire's zero-copy ingest path.  The words must be exactly what
    /// [`crate::sketch::pack_row`] produces for this index's K and
    /// width (length [`crate::sketch::packed_words`]`(K, bits)`, zero
    /// padding bits); the wire boundary validates both before calling.
    /// In packed storage mode the row is memcpy'd into the arena and
    /// band signatures are hashed straight off the packed bits; at
    /// full width (`bits = 32`) the lanes are widened back out and the
    /// ordinary insert runs, so callers need not special-case the
    /// storage mode.
    pub fn insert_packed(&mut self, id: u64, packed: &[u64]) -> crate::Result<()> {
        let want = packed_words(self.k, self.bits);
        if packed.len() != want {
            return Err(crate::Error::ShapeMismatch {
                what: "packed row words",
                expected: want,
                got: packed.len(),
            });
        }
        let r = self.cfg.rows_per_band;
        match &mut self.rows {
            Rows::Full(_) => {
                let lanes = crate::sketch::unpack_row(packed, self.k, self.bits);
                self.insert(id, &lanes)
            }
            Rows::Packed(rows) => {
                if rows.contains(id) {
                    return Err(crate::Error::Invalid(format!("duplicate id {id}")));
                }
                let slot = rows.insert_packed(id, packed);
                let sigs = packed_band_sigs(
                    rows.row(slot),
                    self.cfg.bands,
                    r * self.bits as usize,
                );
                for (table, sig) in self.tables.iter_mut().zip(sigs) {
                    table.entry(sig).or_default().push(slot as u64);
                }
                Ok(())
            }
        }
    }

    /// Remove an id, erasing its band postings in place (tombstone
    /// free: the posting lists shrink immediately, so a deleted item
    /// can never resurface as a candidate).  Returns the removed
    /// sketch (masked to the stored width in packed mode), or `None`
    /// if the id was not present.  The id may be re-inserted
    /// afterwards.
    pub fn remove(&mut self, id: u64) -> Option<Vec<u32>> {
        let r = self.cfg.rows_per_band;
        match &mut self.rows {
            Rows::Full(map) => {
                let sketch = map.remove(&id)?;
                for (b, table) in self.tables.iter_mut().enumerate() {
                    let sig = band_hash(&sketch[b * r..(b + 1) * r]);
                    erase_posting(table, sig, id);
                }
                Some(sketch)
            }
            Rows::Packed(rows) => {
                let slot = rows.slot(id)?;
                let sigs = packed_band_sigs(
                    rows.row(slot),
                    self.cfg.bands,
                    r * self.bits as usize,
                );
                for (table, sig) in self.tables.iter_mut().zip(sigs) {
                    erase_posting(table, sig, slot as u64);
                }
                rows.remove(id)
            }
        }
    }

    /// Iterate stored `(id, sketch)` pairs in unspecified order
    /// (values are masked to the stored width in packed mode).
    pub fn iter(&self) -> Box<dyn Iterator<Item = (u64, Vec<u32>)> + '_> {
        match &self.rows {
            Rows::Full(map) => {
                Box::new(map.iter().map(|(&id, s)| (id, s.clone())))
            }
            Rows::Packed(rows) => Box::new(rows.iter()),
        }
    }

    /// The deduplicated posting values colliding with `sigs` in ≥ 1
    /// band (ids in full mode, slots in packed mode).
    fn collect_postings(&self, sigs: impl Iterator<Item = u64>) -> Vec<u64> {
        let mut out: Vec<u64> = Vec::new();
        for (table, sig) in self.tables.iter().zip(sigs) {
            if let Some(vals) = table.get(&sig) {
                out.extend_from_slice(vals);
            }
        }
        out.sort_unstable();
        out.dedup();
        self.candidates.fetch_add(out.len() as u64, Ordering::Relaxed);
        out
    }

    /// Candidates collected (post-dedup) across this index's lifetime.
    pub fn candidates_collected(&self) -> u64 {
        self.candidates.load(Ordering::Relaxed)
    }

    /// `(occupied band buckets, largest posting list)` — band-table
    /// occupancy for the observability surface: a pathological
    /// collision hot spot shows up as a huge max bucket long before it
    /// shows up as latency.
    pub fn bucket_stats(&self) -> (usize, usize) {
        let buckets = self.tables.iter().map(HashMap::len).sum();
        let max = self
            .tables
            .iter()
            .flat_map(|t| t.values().map(Vec::len))
            .max()
            .unwrap_or(0);
        (buckets, max)
    }

    /// Raw candidate set for a query sketch (ids colliding in ≥1 band).
    pub fn candidates(&self, sketch: &[u32]) -> Vec<u64> {
        let r = self.cfg.rows_per_band;
        match &self.rows {
            Rows::Full(_) => self.collect_postings(
                (0..self.cfg.bands).map(|b| band_hash(&sketch[b * r..(b + 1) * r])),
            ),
            Rows::Packed(rows) => {
                let mut q = vec![0u64; packed_words(self.k, self.bits)];
                pack_row(sketch, self.bits, &mut q);
                let mut ids: Vec<u64> = self
                    .collect_postings(self.packed_sigs(&q).into_iter())
                    .into_iter()
                    .map(|slot| rows.id_at(slot as usize))
                    .collect();
                ids.sort_unstable();
                ids
            }
        }
    }

    /// Score every candidate of `sketch` (unsorted).  Band hashing +
    /// posting collection spans [`Stage::BandLookup`]; candidate
    /// scoring spans [`Stage::Score`] (inert outside a traced request).
    fn scored(&self, sketch: &[u32]) -> Vec<Neighbor> {
        let r = self.cfg.rows_per_band;
        match &self.rows {
            Rows::Full(map) => {
                let postings = {
                    let _span = stage(Stage::BandLookup);
                    self.collect_postings(
                        (0..self.cfg.bands)
                            .map(|b| band_hash(&sketch[b * r..(b + 1) * r])),
                    )
                };
                let _span = stage(Stage::Score);
                postings
                    .into_iter()
                    .filter_map(|id| {
                        // Total lookup: postings and the sketch map are
                        // only ever mutated together under `&mut self`
                        // (insert/remove erase both sides), so a
                        // posting without a row cannot arise from this
                        // module's API — but indexing `map[&id]` here
                        // turned any future desync into a worker panic.
                        // A dangling posting is skipped instead; the
                        // invariant is pinned by
                        // `dangling_posting_is_skipped_not_a_panic`.
                        let row = map.get(&id)?;
                        Some(Neighbor {
                            id,
                            score: estimate(sketch, row),
                        })
                    })
                    .collect()
            }
            Rows::Packed(rows) => {
                let mut q = vec![0u64; packed_words(self.k, self.bits)];
                let postings = {
                    let _span = stage(Stage::BandLookup);
                    pack_row(sketch, self.bits, &mut q);
                    self.collect_postings(self.packed_sigs(&q).into_iter())
                };
                let _span = stage(Stage::Score);
                // Bucket-at-a-time scoring: `collect_postings` returns
                // slots sorted ascending, so the kernel streams the
                // candidate rows out of the arena in address order —
                // one width check for the whole bucket, 4-wide unrolled
                // words, no per-candidate slice plumbing.
                let counts = bucket_collision_counts(
                    &q,
                    rows.arena(),
                    rows.words_per_row(),
                    &postings,
                    self.k,
                    self.bits,
                );
                postings
                    .iter()
                    .zip(counts)
                    .map(|(&slot, c)| Neighbor {
                        id: rows.id_at(slot as usize),
                        score: corrected_estimate(c, self.k, self.bits),
                    })
                    .collect()
            }
        }
    }

    /// Top-k neighbors by (width-corrected) estimate among the
    /// candidates.
    pub fn query(&self, sketch: &[u32], topk: usize) -> Vec<Neighbor> {
        let mut scored = self.scored(sketch);
        sort_neighbors(&mut scored);
        scored.truncate(topk);
        scored
    }

    /// All neighbors with estimate ≥ `threshold`.
    pub fn query_above(&self, sketch: &[u32], threshold: f64) -> Vec<Neighbor> {
        let mut out: Vec<Neighbor> = self
            .scored(sketch)
            .into_iter()
            .filter(|n| n.score >= threshold)
            .collect();
        sort_neighbors(&mut out);
        out
    }

    /// All `(id, packed row words)` pairs when in packed storage mode,
    /// `None` at full width — lets snapshotting copy rows as words
    /// instead of widening every lane to a `u32` only to re-pack it
    /// (a 32/b× transient-memory blowup on large corpora).
    pub fn packed_items(&self) -> Option<Vec<(u64, Vec<u64>)>> {
        match &self.rows {
            Rows::Full(_) => None,
            Rows::Packed(rows) => Some(
                rows.iter_packed()
                    .map(|(id, words)| (id, words.to_vec()))
                    .collect(),
            ),
        }
    }

    /// Stored sketch for an id (masked to the stored width in packed
    /// mode).
    pub fn sketch(&self, id: u64) -> Option<Vec<u32>> {
        match &self.rows {
            Rows::Full(map) => map.get(&id).cloned(),
            Rows::Packed(rows) => rows.get(id),
        }
    }
}

/// Drop one posting value from a signature's list, removing the list
/// when it empties.
fn erase_posting(table: &mut HashMap<u64, Vec<u64>>, sig: u64, value: u64) {
    if let Some(vals) = table.get_mut(&sig) {
        if let Some(pos) = vals.iter().position(|&x| x == value) {
            vals.swap_remove(pos);
        }
        if vals.is_empty() {
            table.remove(&sig);
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::sketch::{CMinHasher, Sketcher};

    fn cfg() -> IndexConfig {
        IndexConfig {
            bands: 16,
            rows_per_band: 4,
        }
    }

    #[test]
    fn s_curve_shape() {
        let c = cfg();
        assert!(c.candidate_probability(0.9) > 0.99);
        assert!(c.candidate_probability(0.1) < 0.01 + 0.01);
        let t = c.threshold();
        assert!(t > 0.3 && t < 0.7, "threshold {t}");
    }

    #[test]
    fn insert_validates() {
        let mut idx = BandingIndex::new(64, cfg()).unwrap();
        assert!(idx.insert(1, &[0u32; 63]).is_err());
        assert!(idx.insert(1, &[0u32; 64]).is_ok());
        assert!(idx.insert(1, &[0u32; 64]).is_err(), "duplicate id");
        assert!(BandingIndex::new(8, cfg()).is_err(), "b*r > K");
        assert!(BandingIndex::with_bits(64, cfg(), 3).is_err(), "odd width");
    }

    #[test]
    fn identical_items_always_found() {
        let h = CMinHasher::new(1024, 64, 5);
        let mut idx = BandingIndex::new(64, cfg()).unwrap();
        let doc: Vec<u32> = (100..200).collect();
        let sk = h.sketch_sparse(&doc);
        idx.insert(42, &sk).unwrap();
        let hits = idx.query(&sk, 3);
        assert_eq!(hits[0].id, 42);
        assert_eq!(hits[0].score, 1.0);
    }

    #[test]
    fn similar_found_dissimilar_not() {
        let h = CMinHasher::new(4096, 128, 9);
        let mut idx = BandingIndex::new(
            128,
            IndexConfig {
                bands: 32,
                rows_per_band: 4,
            },
        )
        .unwrap();
        let base: Vec<u32> = (0..300).map(|i| i * 10).collect();
        let mut near = base.clone();
        near[0] = 7;
        near[1] = 13; // J ~ 298/302
        let far: Vec<u32> = (0..300).map(|i| i * 10 + 5).collect();
        idx.insert(1, &h.sketch_sparse(&near)).unwrap();
        idx.insert(2, &h.sketch_sparse(&far)).unwrap();
        let hits = idx.query(&h.sketch_sparse(&base), 10);
        assert_eq!(hits[0].id, 1, "near duplicate must rank first");
        assert!(hits[0].score > 0.8);
        let above = idx.query_above(&h.sketch_sparse(&base), 0.5);
        assert!(above.iter().all(|n| n.id == 1));
    }

    #[test]
    fn packed_mode_finds_the_same_near_duplicate() {
        // The packed plane must preserve retrieval semantics: exact
        // self-match scores 1.0, the near-duplicate outranks the
        // dissimilar item, and deletes erase candidates — at every
        // supported width.
        let h = CMinHasher::new(4096, 128, 9);
        let base: Vec<u32> = (0..300).map(|i| i * 10).collect();
        let mut near = base.clone();
        near[0] = 7;
        near[1] = 13;
        let far: Vec<u32> = (0..300).map(|i| i * 10 + 5).collect();
        for bits in [1u8, 2, 4, 8, 16] {
            let mut idx = BandingIndex::with_bits(
                128,
                IndexConfig {
                    bands: 16,
                    rows_per_band: 8,
                },
                bits,
            )
            .unwrap();
            idx.insert(1, &h.sketch_sparse(&near)).unwrap();
            idx.insert(2, &h.sketch_sparse(&far)).unwrap();
            let probe = h.sketch_sparse(&base);
            let hits = idx.query(&probe, 10);
            assert_eq!(hits[0].id, 1, "bits={bits}: near duplicate first");
            assert!(hits[0].score > 0.7, "bits={bits}: score {}", hits[0].score);
            // exact self-probe: every lane collides, corrected Ĵ = 1
            let self_hits = idx.query(&h.sketch_sparse(&near), 1);
            assert_eq!(self_hits[0].id, 1, "bits={bits}");
            assert_eq!(self_hits[0].score, 1.0, "bits={bits}");
            assert_eq!(idx.sketch_bytes_per_item(), 16 * bits as usize, "bits={bits}");
        }
    }

    #[test]
    fn remove_erases_postings_and_allows_reinsert() {
        let h = CMinHasher::new(1024, 64, 5);
        let mut idx = BandingIndex::new(64, cfg()).unwrap();
        let doc: Vec<u32> = (100..200).collect();
        let sk = h.sketch_sparse(&doc);
        idx.insert(42, &sk).unwrap();
        idx.insert(43, &h.sketch_sparse(&(300..400).collect::<Vec<_>>()))
            .unwrap();
        assert_eq!(idx.remove(42), Some(sk.clone()));
        assert_eq!(idx.len(), 1);
        assert!(idx.remove(42).is_none(), "double remove is a no-op");
        // deleted item never reappears as a candidate
        assert!(idx.candidates(&sk).is_empty());
        assert!(idx.query(&sk, 5).iter().all(|n| n.id != 42));
        // re-insert under the same id works and is found again
        idx.insert(42, &sk).unwrap();
        assert_eq!(idx.query(&sk, 1)[0].id, 42);
        assert_eq!(idx.iter().count(), 2);
    }

    #[test]
    fn packed_remove_erases_postings_and_recycles_slots() {
        let h = CMinHasher::new(1024, 64, 5);
        let mut idx = BandingIndex::with_bits(64, cfg(), 8).unwrap();
        let sk42 = h.sketch_sparse(&(100..200).collect::<Vec<_>>());
        let sk43 = h.sketch_sparse(&(300..400).collect::<Vec<_>>());
        idx.insert(42, &sk42).unwrap();
        idx.insert(43, &sk43).unwrap();
        let masked: Vec<u32> = sk42.iter().map(|&v| v & 0xff).collect();
        assert_eq!(idx.remove(42), Some(masked));
        assert!(idx.remove(42).is_none());
        assert!(idx.candidates(&sk42).is_empty(), "postings erased");
        assert!(idx.query(&sk42, 5).iter().all(|n| n.id != 42));
        // a new id reuses the freed slot; the old id must not resurface
        idx.insert(44, &sk42).unwrap();
        let hits = idx.query(&sk42, 2);
        assert_eq!(hits[0].id, 44);
        assert_eq!(hits[0].score, 1.0);
        assert!(hits.iter().all(|n| n.id != 42));
        assert_eq!(idx.sketch(43), Some(sk43.iter().map(|&v| v & 0xff).collect()));
        assert_eq!(idx.iter().count(), 2);
    }

    #[test]
    fn insert_packed_is_indistinguishable_from_insert() {
        // the zero-copy ingest path must build identical postings and
        // score identically, in packed AND full storage modes
        let h = CMinHasher::new(1024, 64, 11);
        let docs: Vec<Vec<u32>> = (0..4)
            .map(|i| (i * 50..i * 50 + 120).collect())
            .collect();
        for bits in [4u8, 8, 32] {
            let mut via_lanes = BandingIndex::with_bits(64, cfg(), bits).unwrap();
            let mut via_words = BandingIndex::with_bits(64, cfg(), bits).unwrap();
            for (i, d) in docs.iter().enumerate() {
                let sk = h.sketch_sparse(d);
                via_lanes.insert(i as u64, &sk).unwrap();
                let mut packed = vec![0u64; packed_words(64, bits)];
                pack_row(&sk, bits, &mut packed);
                via_words.insert_packed(i as u64, &packed).unwrap();
            }
            let probe = h.sketch_sparse(&docs[1]);
            assert_eq!(
                via_lanes.query(&probe, 4),
                via_words.query(&probe, 4),
                "bits={bits}"
            );
            // width and duplicate validation hold on this path too
            assert!(via_words.insert_packed(0, &[0u64; 1]).is_err());
            let dup = vec![0u64; packed_words(64, bits)];
            assert!(via_words.insert_packed(0, &dup).is_err(), "duplicate id");
        }
    }

    #[test]
    fn bucket_stats_and_candidate_counter_track_activity() {
        let mut idx =
            BandingIndex::new(8, IndexConfig { bands: 4, rows_per_band: 2 }).unwrap();
        assert_eq!(idx.bucket_stats(), (0, 0), "empty index");
        assert_eq!(idx.candidates_collected(), 0);
        let sk = vec![1u32; 8];
        idx.insert(7, &sk).unwrap();
        idx.insert(8, &sk).unwrap();
        let (buckets, max) = idx.bucket_stats();
        assert_eq!(buckets, 4, "identical rows share one bucket per band");
        assert_eq!(max, 2, "both items in each bucket");
        idx.query(&sk, 10);
        assert_eq!(idx.candidates_collected(), 2, "post-dedup candidate count");
        idx.query(&[9u32; 8], 10);
        assert_eq!(idx.candidates_collected(), 2, "miss adds no candidates");
        idx.remove(8);
        let (buckets, max) = idx.bucket_stats();
        assert_eq!((buckets, max), (4, 1), "postings shrink with deletes");
    }

    #[test]
    fn dangling_posting_is_skipped_not_a_panic() {
        // Regression for the `map[&id]` panic: a posting whose sketch
        // row is gone (a desync no public path produces, simulated here
        // through the private fields) must be skipped by scoring, not
        // take the worker down.
        let h = CMinHasher::new(1024, 64, 5);
        let mut idx = BandingIndex::new(64, cfg()).unwrap();
        let ska = h.sketch_sparse(&(100..200).collect::<Vec<_>>());
        let skb = h.sketch_sparse(&(300..400).collect::<Vec<_>>());
        idx.insert(1, &ska).unwrap();
        idx.insert(2, &skb).unwrap();
        match &mut idx.rows {
            Rows::Full(map) => {
                map.remove(&1);
            }
            Rows::Packed(_) => unreachable!("bits=32 stores full rows"),
        }
        let hits = idx.query(&ska, 5);
        assert!(hits.iter().all(|n| n.id != 1), "dangling id must not score");
        assert_eq!(idx.query(&skb, 1)[0].id, 2, "live items still served");
    }

    #[test]
    fn remove_query_interleaving_never_dangles() {
        // The invariant behind the total lookup: any interleaving of
        // insert/remove/query through the public API keeps postings and
        // rows in lockstep — removed ids never resurface, live ids keep
        // scoring — in both storage modes.
        let h = CMinHasher::new(1024, 64, 13);
        for bits in [8u8, 32] {
            let mut idx = BandingIndex::with_bits(64, cfg(), bits).unwrap();
            let sks: Vec<Vec<u32>> = (0..20u32)
                .map(|i| {
                    h.sketch_sparse(&(i * 17..i * 17 + 60).collect::<Vec<_>>())
                })
                .collect();
            for (i, sk) in sks.iter().enumerate() {
                idx.insert(i as u64, sk).unwrap();
            }
            let mut removed = std::collections::HashSet::new();
            for round in 0..20usize {
                let victim = (round * 7 % 20) as u64;
                if removed.insert(victim) {
                    assert!(idx.remove(victim).is_some(), "bits={bits}");
                }
                for sk in &sks {
                    for n in idx.query(sk, 20) {
                        assert!(
                            !removed.contains(&n.id),
                            "bits={bits}: removed id {} resurfaced",
                            n.id
                        );
                    }
                }
            }
            assert_eq!(idx.len(), 20 - removed.len(), "bits={bits}");
        }
    }

    #[test]
    fn candidates_dedup() {
        let mut idx = BandingIndex::new(8, IndexConfig { bands: 4, rows_per_band: 2 }).unwrap();
        let sk = vec![1u32; 8];
        idx.insert(7, &sk).unwrap();
        // identical sketch collides in all 4 bands but appears once
        assert_eq!(idx.candidates(&sk), vec![7]);
    }

    #[test]
    fn packed_candidates_dedup_and_translate_slots_to_ids() {
        for bits in [1u8, 4, 16] {
            let mut idx = BandingIndex::with_bits(
                8,
                IndexConfig {
                    bands: 4,
                    rows_per_band: 2,
                },
                bits,
            )
            .unwrap();
            let sk = vec![1u32; 8];
            idx.insert(7, &sk).unwrap();
            assert_eq!(idx.candidates(&sk), vec![7], "bits={bits}");
        }
    }
}
