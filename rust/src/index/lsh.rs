//! Banding LSH over MinHash-style sketches.
//!
//! A sketch of K values is split into `bands` bands of `rows_per_band`
//! values; each band is hashed into a table.  Two items collide in a
//! band with probability J^r, and in at least one band with probability
//! 1 − (1 − J^r)^b — the classic S-curve.  Candidates are re-ranked by
//! the full-sketch collision estimate.

use crate::sketch::estimate;
use std::collections::HashMap;

/// Band configuration.  `bands * rows_per_band` must be ≤ K.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct IndexConfig {
    /// Number of bands b.
    pub bands: usize,
    /// Rows per band r.
    pub rows_per_band: usize,
}

impl IndexConfig {
    /// The probability that a pair with Jaccard `j` becomes a candidate:
    /// 1 − (1 − j^r)^b.
    pub fn candidate_probability(&self, j: f64) -> f64 {
        1.0 - (1.0 - j.powi(self.rows_per_band as i32)).powi(self.bands as i32)
    }

    /// The similarity threshold where the S-curve is steepest,
    /// ≈ (1/b)^(1/r).
    pub fn threshold(&self) -> f64 {
        (1.0 / self.bands as f64).powf(1.0 / self.rows_per_band as f64)
    }
}

/// A scored neighbor returned by queries.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Neighbor {
    /// Item id (as assigned at insert time).
    pub id: u64,
    /// Full-sketch collision estimate Ĵ.
    pub score: f64,
}

/// Sort neighbors into the one total result order every query path
/// shares — score descending, then id ascending.  The sharded store
/// merges per-shard results with this same function, which is what
/// makes sharding a pure scaling knob (N = 1 byte-identical to the
/// bare index, N > 1 merged deterministically).
pub fn sort_neighbors(xs: &mut [Neighbor]) {
    xs.sort_by(|x, y| y.score.total_cmp(&x.score).then(x.id.cmp(&y.id)));
}

/// The banding index: b hash tables over band signatures, plus the
/// stored sketches for re-ranking.
#[derive(Debug)]
pub struct BandingIndex {
    cfg: IndexConfig,
    k: usize,
    tables: Vec<HashMap<u64, Vec<u64>>>,
    sketches: HashMap<u64, Vec<u32>>,
}

/// FNV-1a over a band's u32 values — cheap, deterministic, dependency
/// free.
#[inline]
fn band_hash(values: &[u32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &v in values {
        for b in v.to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

impl BandingIndex {
    /// Create an index over sketches of length `k`.
    pub fn new(k: usize, cfg: IndexConfig) -> crate::Result<Self> {
        if cfg.bands == 0 || cfg.rows_per_band == 0 {
            return Err(crate::Error::Invalid("bands and rows must be > 0".into()));
        }
        if cfg.bands * cfg.rows_per_band > k {
            return Err(crate::Error::Invalid(format!(
                "bands({}) * rows({}) > K({k})",
                cfg.bands, cfg.rows_per_band
            )));
        }
        Ok(BandingIndex {
            cfg,
            k,
            tables: vec![HashMap::new(); cfg.bands],
            sketches: HashMap::new(),
        })
    }

    /// Configuration.
    pub fn config(&self) -> IndexConfig {
        self.cfg
    }

    /// Number of indexed items.
    pub fn len(&self) -> usize {
        self.sketches.len()
    }

    /// True iff no items are indexed.
    pub fn is_empty(&self) -> bool {
        self.sketches.is_empty()
    }

    /// Insert an item's sketch under `id` (overwrites an existing id's
    /// sketch store entry but not its stale table entries — ids are
    /// expected unique, enforced here).
    pub fn insert(&mut self, id: u64, sketch: &[u32]) -> crate::Result<()> {
        if sketch.len() != self.k {
            return Err(crate::Error::ShapeMismatch {
                what: "sketch",
                expected: self.k,
                got: sketch.len(),
            });
        }
        if self.sketches.contains_key(&id) {
            return Err(crate::Error::Invalid(format!("duplicate id {id}")));
        }
        let r = self.cfg.rows_per_band;
        for (b, table) in self.tables.iter_mut().enumerate() {
            let sig = band_hash(&sketch[b * r..(b + 1) * r]);
            table.entry(sig).or_default().push(id);
        }
        self.sketches.insert(id, sketch.to_vec());
        Ok(())
    }

    /// Remove an id, erasing its band postings in place (tombstone
    /// free: the posting lists shrink immediately, so a deleted item
    /// can never resurface as a candidate).  Returns the removed
    /// sketch, or `None` if the id was not present.  The id may be
    /// re-inserted afterwards.
    pub fn remove(&mut self, id: u64) -> Option<Vec<u32>> {
        let sketch = self.sketches.remove(&id)?;
        let r = self.cfg.rows_per_band;
        for (b, table) in self.tables.iter_mut().enumerate() {
            let sig = band_hash(&sketch[b * r..(b + 1) * r]);
            if let Some(ids) = table.get_mut(&sig) {
                if let Some(pos) = ids.iter().position(|&x| x == id) {
                    ids.swap_remove(pos);
                }
                if ids.is_empty() {
                    table.remove(&sig);
                }
            }
        }
        Some(sketch)
    }

    /// Iterate stored `(id, sketch)` pairs in unspecified order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, &[u32])> + '_ {
        self.sketches.iter().map(|(&id, s)| (id, s.as_slice()))
    }

    /// Raw candidate set for a query sketch (ids colliding in ≥1 band).
    pub fn candidates(&self, sketch: &[u32]) -> Vec<u64> {
        let r = self.cfg.rows_per_band;
        let mut out: Vec<u64> = Vec::new();
        for (b, table) in self.tables.iter().enumerate() {
            let sig = band_hash(&sketch[b * r..(b + 1) * r]);
            if let Some(ids) = table.get(&sig) {
                out.extend_from_slice(ids);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    /// Top-k neighbors by full-sketch estimate among the candidates.
    pub fn query(&self, sketch: &[u32], topk: usize) -> Vec<Neighbor> {
        let mut scored: Vec<Neighbor> = self
            .candidates(sketch)
            .into_iter()
            .map(|id| Neighbor {
                id,
                score: estimate(sketch, &self.sketches[&id]),
            })
            .collect();
        sort_neighbors(&mut scored);
        scored.truncate(topk);
        scored
    }

    /// All neighbors with estimate ≥ `threshold`.
    pub fn query_above(&self, sketch: &[u32], threshold: f64) -> Vec<Neighbor> {
        let mut out: Vec<Neighbor> = self
            .candidates(sketch)
            .into_iter()
            .map(|id| Neighbor {
                id,
                score: estimate(sketch, &self.sketches[&id]),
            })
            .filter(|n| n.score >= threshold)
            .collect();
        sort_neighbors(&mut out);
        out
    }

    /// Stored sketch for an id.
    pub fn sketch(&self, id: u64) -> Option<&[u32]> {
        self.sketches.get(&id).map(|s| s.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sketch::{CMinHasher, Sketcher};

    fn cfg() -> IndexConfig {
        IndexConfig {
            bands: 16,
            rows_per_band: 4,
        }
    }

    #[test]
    fn s_curve_shape() {
        let c = cfg();
        assert!(c.candidate_probability(0.9) > 0.99);
        assert!(c.candidate_probability(0.1) < 0.01 + 0.01);
        let t = c.threshold();
        assert!(t > 0.3 && t < 0.7, "threshold {t}");
    }

    #[test]
    fn insert_validates() {
        let mut idx = BandingIndex::new(64, cfg()).unwrap();
        assert!(idx.insert(1, &[0u32; 63]).is_err());
        assert!(idx.insert(1, &[0u32; 64]).is_ok());
        assert!(idx.insert(1, &[0u32; 64]).is_err(), "duplicate id");
        assert!(BandingIndex::new(8, cfg()).is_err(), "b*r > K");
    }

    #[test]
    fn identical_items_always_found() {
        let h = CMinHasher::new(1024, 64, 5);
        let mut idx = BandingIndex::new(64, cfg()).unwrap();
        let doc: Vec<u32> = (100..200).collect();
        let sk = h.sketch_sparse(&doc);
        idx.insert(42, &sk).unwrap();
        let hits = idx.query(&sk, 3);
        assert_eq!(hits[0].id, 42);
        assert_eq!(hits[0].score, 1.0);
    }

    #[test]
    fn similar_found_dissimilar_not() {
        let h = CMinHasher::new(4096, 128, 9);
        let mut idx = BandingIndex::new(
            128,
            IndexConfig {
                bands: 32,
                rows_per_band: 4,
            },
        )
        .unwrap();
        let base: Vec<u32> = (0..300).map(|i| i * 10).collect();
        let mut near = base.clone();
        near[0] = 7;
        near[1] = 13; // J ~ 298/302
        let far: Vec<u32> = (0..300).map(|i| i * 10 + 5).collect();
        idx.insert(1, &h.sketch_sparse(&near)).unwrap();
        idx.insert(2, &h.sketch_sparse(&far)).unwrap();
        let hits = idx.query(&h.sketch_sparse(&base), 10);
        assert_eq!(hits[0].id, 1, "near duplicate must rank first");
        assert!(hits[0].score > 0.8);
        let above = idx.query_above(&h.sketch_sparse(&base), 0.5);
        assert!(above.iter().all(|n| n.id == 1));
    }

    #[test]
    fn remove_erases_postings_and_allows_reinsert() {
        let h = CMinHasher::new(1024, 64, 5);
        let mut idx = BandingIndex::new(64, cfg()).unwrap();
        let doc: Vec<u32> = (100..200).collect();
        let sk = h.sketch_sparse(&doc);
        idx.insert(42, &sk).unwrap();
        idx.insert(43, &h.sketch_sparse(&(300..400).collect::<Vec<_>>()))
            .unwrap();
        assert_eq!(idx.remove(42), Some(sk.clone()));
        assert_eq!(idx.len(), 1);
        assert!(idx.remove(42).is_none(), "double remove is a no-op");
        // deleted item never reappears as a candidate
        assert!(idx.candidates(&sk).is_empty());
        assert!(idx.query(&sk, 5).iter().all(|n| n.id != 42));
        // re-insert under the same id works and is found again
        idx.insert(42, &sk).unwrap();
        assert_eq!(idx.query(&sk, 1)[0].id, 42);
        assert_eq!(idx.iter().count(), 2);
    }

    #[test]
    fn candidates_dedup() {
        let mut idx = BandingIndex::new(8, IndexConfig { bands: 4, rows_per_band: 2 }).unwrap();
        let sk = vec![1u32; 8];
        idx.insert(7, &sk).unwrap();
        // identical sketch collides in all 4 bands but appears once
        assert_eq!(idx.candidates(&sk), vec![7]);
    }
}
