//! Approximate near-neighbor search over sketches — the banding LSH
//! index that motivates MinHash in the first place (Indyk–Motwani
//! style hash tables; the paper's intro cites ANN as the regime where
//! K must grow beyond 1024, which is exactly where C-MinHash's
//! two-permutation memory story matters).

mod lsh;

pub use lsh::{sort_neighbors, BandingIndex, IndexConfig, Neighbor};
