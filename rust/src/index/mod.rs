//! Approximate near-neighbor search over sketches — the banding LSH
//! index that motivates MinHash in the first place (Indyk–Motwani
//! style hash tables; the paper's intro cites ANN as the regime where
//! K must grow beyond 1024, which is exactly where C-MinHash's
//! two-permutation memory story matters).
//!
//! The index stores rows either full-width (`Vec<u32>` per item) or
//! packed — K·b-bit rows in one contiguous [`PackedRows`] bit-matrix,
//! banded and scored without unpacking (see `rust/src/sketch/bbit.rs`
//! for the lane codec and the XOR+popcount kernel).

mod lsh;
mod packed;

pub use lsh::{sort_neighbors, BandingIndex, IndexConfig, Neighbor};
pub use packed::PackedRows;
