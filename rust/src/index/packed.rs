//! Contiguous packed row storage for the b-bit serving plane.
//!
//! One arena of `u64` words holds every resident sketch row-major —
//! K·b bits per item, [`crate::sketch::packed_words`]`(K, b)` words
//! per row — instead of one heap `Vec<u32>` per item.  Rows are
//! addressed by *slot*; a slot map translates item ids, freed slots
//! are recycled, and the banding index stores slots (not ids) in its
//! postings so the query hot loop reads candidate rows straight out
//! of the arena with no per-candidate hash lookup.

use crate::sketch::{pack_row, packed_words, unpack_row};
use std::collections::HashMap;

/// A contiguous bit-matrix of packed b-bit sketch rows with id→slot
/// addressing and slot recycling.
#[derive(Debug)]
pub struct PackedRows {
    bits: u8,
    k: usize,
    /// Words per row.
    wpr: usize,
    /// The arena: `capacity × wpr` words, row-major.
    words: Vec<u64>,
    slot_of: HashMap<u64, usize>,
    /// Slot → owning id (stale for free slots, which hold zeroed rows).
    id_of: Vec<u64>,
    free: Vec<usize>,
}

impl PackedRows {
    /// An empty store for K-lane rows at `bits` per lane.
    pub fn new(k: usize, bits: u8) -> Self {
        PackedRows {
            bits,
            k,
            wpr: packed_words(k, bits),
            words: Vec::new(),
            slot_of: HashMap::new(),
            id_of: Vec::new(),
            free: Vec::new(),
        }
    }

    /// Bits per lane.
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Words per packed row.
    pub fn words_per_row(&self) -> usize {
        self.wpr
    }

    /// Number of resident rows.
    pub fn len(&self) -> usize {
        self.slot_of.len()
    }

    /// True iff no rows are resident.
    pub fn is_empty(&self) -> bool {
        self.slot_of.is_empty()
    }

    /// Arena footprint in bytes (allocated rows, including recycled
    /// free slots — the number that actually sits in RAM).
    pub fn arena_bytes(&self) -> usize {
        self.words.len() * 8
    }

    /// True iff `id` has a resident row.
    pub fn contains(&self, id: u64) -> bool {
        self.slot_of.contains_key(&id)
    }

    /// The slot holding `id`'s row.
    pub fn slot(&self, id: u64) -> Option<usize> {
        self.slot_of.get(&id).copied()
    }

    /// The id owning `slot` (only meaningful for occupied slots).
    pub fn id_at(&self, slot: usize) -> u64 {
        self.id_of[slot]
    }

    /// The packed words of `slot`'s row.
    pub fn row(&self, slot: usize) -> &[u64] {
        &self.words[slot * self.wpr..(slot + 1) * self.wpr]
    }

    /// The whole arena — `capacity × words_per_row` words, row-major,
    /// freed slots zeroed.  The batch scoring kernel
    /// ([`crate::sketch::bucket_collision_counts`]) streams candidate
    /// rows straight out of this slice in slot order, which is why the
    /// layout keeps rows contiguous and never interleaves metadata.
    pub fn arena(&self) -> &[u64] {
        &self.words
    }

    /// Pack `full` (length K; values are masked to b bits) under `id`
    /// and return the slot.  The caller guarantees `id` is not already
    /// resident and the length matches K.
    pub fn insert(&mut self, id: u64, full: &[u32]) -> usize {
        debug_assert_eq!(full.len(), self.k);
        debug_assert!(!self.slot_of.contains_key(&id), "duplicate id {id}");
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                let s = self.id_of.len();
                self.id_of.push(0);
                self.words.resize(self.words.len() + self.wpr, 0);
                s
            }
        };
        pack_row(
            full,
            self.bits,
            &mut self.words[slot * self.wpr..(slot + 1) * self.wpr],
        );
        self.id_of[slot] = id;
        self.slot_of.insert(id, slot);
        slot
    }

    /// Store an already-packed row (exactly [`words_per_row`] words,
    /// as produced by [`crate::sketch::pack_row`]) under `id` and
    /// return the slot — the binary-ingest path: one `copy_from_slice`
    /// into the arena, no per-lane unpack/repack.  The caller
    /// guarantees `id` is not already resident, the width matches, and
    /// padding bits beyond K·b are zero (enforced at the wire
    /// boundary; nonzero padding would corrupt popcount scoring).
    ///
    /// [`words_per_row`]: PackedRows::words_per_row
    pub fn insert_packed(&mut self, id: u64, packed: &[u64]) -> usize {
        debug_assert_eq!(packed.len(), self.wpr);
        debug_assert!(!self.slot_of.contains_key(&id), "duplicate id {id}");
        let slot = match self.free.pop() {
            Some(s) => s,
            None => {
                let s = self.id_of.len();
                self.id_of.push(0);
                self.words.resize(self.words.len() + self.wpr, 0);
                s
            }
        };
        self.words[slot * self.wpr..(slot + 1) * self.wpr].copy_from_slice(packed);
        self.id_of[slot] = id;
        self.slot_of.insert(id, slot);
        slot
    }

    /// Remove `id`'s row, returning its masked lane values (what
    /// [`PackedRows::get`] would have returned) and recycling the
    /// slot.  `None` if the id is not resident.
    pub fn remove(&mut self, id: u64) -> Option<Vec<u32>> {
        let slot = self.slot_of.remove(&id)?;
        let row = unpack_row(self.row(slot), self.k, self.bits);
        for w in &mut self.words[slot * self.wpr..(slot + 1) * self.wpr] {
            *w = 0;
        }
        self.free.push(slot);
        Some(row)
    }

    /// The masked lane values stored for `id`.
    pub fn get(&self, id: u64) -> Option<Vec<u32>> {
        self.slot(id)
            .map(|s| unpack_row(self.row(s), self.k, self.bits))
    }

    /// Iterate `(id, masked lane values)` in unspecified order.
    pub fn iter(&self) -> impl Iterator<Item = (u64, Vec<u32>)> + '_ {
        self.slot_of
            .iter()
            .map(move |(&id, &s)| (id, unpack_row(self.row(s), self.k, self.bits)))
    }

    /// Iterate `(id, packed row words)` in unspecified order — the
    /// allocation-light path for snapshotting: rows leave as the words
    /// they are stored as, never widened.
    pub fn iter_packed(&self) -> impl Iterator<Item = (u64, &[u64])> + '_ {
        self.slot_of.iter().map(move |(&id, &s)| (id, self.row(s)))
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn insert_get_remove_roundtrip_masks_lanes() {
        let mut rows = PackedRows::new(6, 4);
        let full = vec![0u32, 15, 16, 255, 7, 9];
        let masked = vec![0u32, 15, 0, 15, 7, 9];
        let slot = rows.insert(42, &full);
        assert_eq!(rows.len(), 1);
        assert!(rows.contains(42));
        assert_eq!(rows.slot(42), Some(slot));
        assert_eq!(rows.id_at(slot), 42);
        assert_eq!(rows.get(42), Some(masked.clone()));
        assert_eq!(rows.remove(42), Some(masked));
        assert!(rows.is_empty());
        assert!(rows.remove(42).is_none());
    }

    #[test]
    fn slots_are_recycled_and_rows_zeroed() {
        let mut rows = PackedRows::new(8, 8);
        let a: Vec<u32> = (0..8).map(|i| i * 3 + 1).collect();
        let b: Vec<u32> = (0..8).map(|i| i * 5 + 2).collect();
        let sa = rows.insert(1, &a);
        rows.insert(2, &b);
        let bytes = rows.arena_bytes();
        rows.remove(1).unwrap();
        assert!(rows.row(sa).iter().all(|&w| w == 0), "freed row zeroed");
        // the freed slot is reused; the arena does not grow
        let sc = rows.insert(3, &a);
        assert_eq!(sc, sa);
        assert_eq!(rows.arena_bytes(), bytes);
        assert_eq!(rows.get(3), Some(a));
        assert_eq!(rows.get(2), Some(b));
        let mut ids: Vec<u64> = rows.iter().map(|(id, _)| id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![2, 3]);
    }

    #[test]
    fn insert_packed_matches_insert() {
        // shipping pre-packed words must land bit-identically to
        // packing the lanes server-side
        let full: Vec<u32> = (0..12).map(|i| i * 41 % 256).collect();
        let mut via_lanes = PackedRows::new(12, 8);
        let mut via_words = PackedRows::new(12, 8);
        let slot = via_lanes.insert(5, &full);
        let packed = via_lanes.row(slot).to_vec();
        let slot2 = via_words.insert_packed(5, &packed);
        assert_eq!(via_words.row(slot2), &packed[..]);
        assert_eq!(via_words.get(5), via_lanes.get(5));
        // freed slots are recycled on this path too
        via_words.remove(5).unwrap();
        assert_eq!(via_words.insert_packed(6, &packed), slot2);
    }

    #[test]
    fn partial_last_word_is_handled() {
        // K = 5 at b = 16 ends mid-word (80 bits → 2 words).
        let mut rows = PackedRows::new(5, 16);
        assert_eq!(rows.words_per_row(), 2);
        let full = vec![1u32, 70000, 65535, 0, 31];
        rows.insert(9, &full);
        assert_eq!(rows.get(9), Some(vec![1, 70000 % 65536, 65535, 0, 31]));
        assert_eq!(rows.arena_bytes(), 16);
    }
}
