//! Lightweight serving metrics: atomic counters and a log-bucketed
//! latency histogram, snapshotted to JSON by the `/stats` endpoint and
//! rendered as Prometheus text by [`crate::obs::prom`].

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Number of log2 latency buckets (1us … ~17min).  Bucket `i` counts
/// observations in `[2^i, 2^(i+1))` µs (bucket 0 also holds 0 µs; the
/// last bucket holds everything above its lower bound).
pub const BUCKETS: usize = 30;

/// A log2-bucketed histogram of microsecond latencies.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl LatencyHistogram {
    /// Record one observation in microseconds.
    pub fn record(&self, us: u64) {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of all observations in microseconds.
    pub fn sum_us(&self) -> u64 {
        self.sum_us.load(Ordering::Relaxed)
    }

    /// The raw bucket counts (bucket `i` = observations in
    /// `[2^i, 2^(i+1))` µs) — the full distribution, exported by
    /// `stats` and the Prometheus surface.
    pub fn buckets(&self) -> [u64; BUCKETS] {
        std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed))
    }

    /// Approximate quantile from bucket boundaries: the upper bound of
    /// the bucket containing the q-quantile, clamped to the observed
    /// maximum (a bucket's nominal upper bound can exceed any value
    /// actually recorded — e.g. one 100000µs sample lands in the
    /// [65536, 131072) bucket, and an unclamped p99 would report
    /// 131072µs, above every observation).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let max = self.max_us.load(Ordering::Relaxed);
        let target = (q.clamp(0.0, 1.0) * n as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << (i + 1)).min(max);
            }
        }
        max
    }

    /// Maximum observed latency.
    pub fn max_us(&self) -> u64 {
        self.max_us.load(Ordering::Relaxed)
    }

    /// Mean latency in microseconds (0 if empty).
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }
}

/// Snapshot of one histogram for JSON export.
#[derive(Clone, Debug, PartialEq)]
pub struct LatencySnapshot {
    /// Observation count.
    pub count: u64,
    /// Mean microseconds.
    pub mean_us: f64,
    /// ~p50 upper bound.
    pub p50_us: u64,
    /// ~p99 upper bound.
    pub p99_us: u64,
    /// Max microseconds.
    pub max_us: u64,
    /// Sum of all observations (µs) — with `count`, the Prometheus
    /// `_sum`/`_count` pair.
    pub sum_us: u64,
    /// Raw log2 bucket counts ([`BUCKETS`] entries; bucket `i` counts
    /// `[2^i, 2^(i+1))` µs).
    pub buckets: Vec<u64>,
}

impl Default for LatencySnapshot {
    fn default() -> Self {
        (&LatencyHistogram::default()).into()
    }
}

impl From<&LatencyHistogram> for LatencySnapshot {
    fn from(h: &LatencyHistogram) -> Self {
        LatencySnapshot {
            count: h.count(),
            mean_us: h.mean_us(),
            p50_us: h.quantile_us(0.5),
            p99_us: h.quantile_us(0.99),
            max_us: h.max_us(),
            sum_us: h.sum_us(),
            buckets: h.buckets().to_vec(),
        }
    }
}

/// All serving metrics.
#[derive(Debug)]
pub struct Metrics {
    /// End-to-end sketch request latency.
    pub sketch_latency: LatencyHistogram,
    /// Engine execute latency (per batch).
    pub batch_latency: LatencyHistogram,
    /// Query latency.
    pub query_latency: LatencyHistogram,
    /// Estimate latency (`estimate` and `estimate_vecs` ops).
    pub estimate_latency: LatencyHistogram,
    /// Total sketch requests served.
    pub sketches: AtomicU64,
    /// Total batches executed.
    pub batches: AtomicU64,
    /// Batches routed to the sparse (gather) artifact.
    pub sparse_batches: AtomicU64,
    /// Total rows padded into partial batches.
    pub pad_rows: AtomicU64,
    /// Total queries served.
    pub queries: AtomicU64,
    /// Total estimates served.
    pub estimates: AtomicU64,
    /// Total deletes applied.
    pub deletes: AtomicU64,
    /// Requests rejected with an error.
    pub errors: AtomicU64,
    /// Malformed binary frames (bad checksum, truncated mid-frame,
    /// oversized declared length, unknown op, undecodable payload) on
    /// `bin1`-negotiated connections.  Kept separate from `errors` so
    /// wire corruption is distinguishable from semantically invalid
    /// requests.
    pub frame_errors: AtomicU64,
    /// Connections turned away with a `busy` error (pool saturated).
    pub busy_rejections: AtomicU64,
    /// Transient accept() failures survived by the accept loop.
    pub accept_errors: AtomicU64,
    /// Cluster fan-out sub-requests that failed and were skipped —
    /// each one a degraded partial merge (counted by the cluster
    /// client, which owns its own registry).
    pub node_errors: AtomicU64,
    /// When this metrics registry was created (service start).
    started: Instant,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            sketch_latency: LatencyHistogram::default(),
            batch_latency: LatencyHistogram::default(),
            query_latency: LatencyHistogram::default(),
            estimate_latency: LatencyHistogram::default(),
            sketches: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            sparse_batches: AtomicU64::new(0),
            pad_rows: AtomicU64::new(0),
            queries: AtomicU64::new(0),
            estimates: AtomicU64::new(0),
            deletes: AtomicU64::new(0),
            errors: AtomicU64::new(0),
            frame_errors: AtomicU64::new(0),
            busy_rejections: AtomicU64::new(0),
            accept_errors: AtomicU64::new(0),
            node_errors: AtomicU64::new(0),
            started: Instant::now(),
        }
    }
}

/// JSON-serializable snapshot of [`Metrics`].
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Sketch latency stats.
    pub sketch_latency: LatencySnapshot,
    /// Batch execute latency stats.
    pub batch_latency: LatencySnapshot,
    /// Query latency stats.
    pub query_latency: LatencySnapshot,
    /// Estimate latency stats.
    pub estimate_latency: LatencySnapshot,
    /// Counter values.
    pub sketches: u64,
    /// Batches executed.
    pub batches: u64,
    /// Batches routed to the sparse artifact.
    pub sparse_batches: u64,
    /// Padding rows.
    pub pad_rows: u64,
    /// Queries served.
    pub queries: u64,
    /// Estimates served.
    pub estimates: u64,
    /// Deletes applied.
    pub deletes: u64,
    /// Errors returned.
    pub errors: u64,
    /// Malformed binary frames survived.
    pub frame_errors: u64,
    /// Connections rejected busy.
    pub busy_rejections: u64,
    /// Accept failures survived.
    pub accept_errors: u64,
    /// Cluster sub-requests skipped (degraded merges).
    pub node_errors: u64,
    /// Mean rows per executed batch.
    pub mean_batch_fill: f64,
    /// Seconds since service start.
    pub uptime_s: f64,
}

impl LatencySnapshot {
    /// JSON form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("mean_us", Json::Num(self.mean_us)),
            ("p50_us", Json::Num(self.p50_us as f64)),
            ("p99_us", Json::Num(self.p99_us as f64)),
            ("max_us", Json::Num(self.max_us as f64)),
            ("sum_us", Json::Num(self.sum_us as f64)),
            (
                "buckets",
                Json::Arr(
                    self.buckets
                        .iter()
                        .map(|&b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
        ])
    }
}

impl MetricsSnapshot {
    /// JSON form (the `/stats` payload).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("sketch_latency", self.sketch_latency.to_json()),
            ("batch_latency", self.batch_latency.to_json()),
            ("query_latency", self.query_latency.to_json()),
            ("estimate_latency", self.estimate_latency.to_json()),
            ("sketches", Json::Num(self.sketches as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("sparse_batches", Json::Num(self.sparse_batches as f64)),
            ("pad_rows", Json::Num(self.pad_rows as f64)),
            ("queries", Json::Num(self.queries as f64)),
            ("estimates", Json::Num(self.estimates as f64)),
            ("deletes", Json::Num(self.deletes as f64)),
            ("errors", Json::Num(self.errors as f64)),
            ("frame_errors", Json::Num(self.frame_errors as f64)),
            ("busy_rejections", Json::Num(self.busy_rejections as f64)),
            ("accept_errors", Json::Num(self.accept_errors as f64)),
            ("node_errors", Json::Num(self.node_errors as f64)),
            ("mean_batch_fill", Json::Num(self.mean_batch_fill)),
            ("uptime_s", Json::Num(self.uptime_s)),
        ])
    }
}

impl Metrics {
    /// Take a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let batches = self.batches.load(Ordering::Relaxed);
        let sketches = self.sketches.load(Ordering::Relaxed);
        MetricsSnapshot {
            sketch_latency: (&self.sketch_latency).into(),
            batch_latency: (&self.batch_latency).into(),
            query_latency: (&self.query_latency).into(),
            estimate_latency: (&self.estimate_latency).into(),
            sketches,
            batches,
            sparse_batches: self.sparse_batches.load(Ordering::Relaxed),
            pad_rows: self.pad_rows.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            estimates: self.estimates.load(Ordering::Relaxed),
            deletes: self.deletes.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            frame_errors: self.frame_errors.load(Ordering::Relaxed),
            busy_rejections: self.busy_rejections.load(Ordering::Relaxed),
            accept_errors: self.accept_errors.load(Ordering::Relaxed),
            node_errors: self.node_errors.load(Ordering::Relaxed),
            mean_batch_fill: if batches == 0 {
                0.0
            } else {
                sketches as f64 / batches as f64
            },
            uptime_s: self.started.elapsed().as_secs_f64(),
        }
    }

    /// Bump a counter by one.
    pub fn inc(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_monotone() {
        let h = LatencyHistogram::default();
        for us in [1u64, 10, 100, 1000, 10_000, 100_000] {
            for _ in 0..10 {
                h.record(us);
            }
        }
        assert_eq!(h.count(), 60);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
        assert_eq!(h.max_us(), 100_000);
    }

    #[test]
    fn quantile_never_exceeds_observed_max() {
        // Regression: 100000µs lands in the [65536, 131072) bucket and
        // the unclamped quantile reported the bucket's upper bound
        // 131072µs — above every value ever recorded.
        let h = LatencyHistogram::default();
        for _ in 0..100 {
            h.record(100_000);
        }
        assert_eq!(h.quantile_us(0.99), 100_000);
        assert_eq!(h.quantile_us(0.5), 100_000);
        assert_eq!(h.max_us(), 100_000);
        // mixed distribution: every quantile stays within [0, max]
        h.record(3);
        h.record(700);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            assert!(h.quantile_us(q) <= h.max_us(), "q={q}");
        }
        // low quantiles of small values are unaffected by the clamp
        let h2 = LatencyHistogram::default();
        h2.record(1);
        h2.record(1_000_000);
        assert_eq!(h2.quantile_us(0.5), 2, "bucket bound, not the max");
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::default();
        assert_eq!(h.quantile_us(0.5), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert_eq!(h.sum_us(), 0);
        assert!(h.buckets().iter().all(|&b| b == 0));
    }

    #[test]
    fn buckets_export_the_full_distribution() {
        let h = LatencyHistogram::default();
        h.record(0); // bucket 0 (us.max(1))
        h.record(1); // bucket 0
        h.record(2); // bucket 1
        h.record(1024); // bucket 10
        h.record(u64::MAX); // clamped into the last bucket
        let b = h.buckets();
        assert_eq!(b[0], 2);
        assert_eq!(b[1], 1);
        assert_eq!(b[10], 1);
        assert_eq!(b[BUCKETS - 1], 1);
        assert_eq!(b.iter().sum::<u64>(), h.count());
        let snap = LatencySnapshot::from(&h);
        assert_eq!(snap.buckets, b.to_vec());
        assert_eq!(snap.sum_us, h.sum_us());
    }

    #[test]
    fn snapshot_computes_fill() {
        let m = Metrics::default();
        m.sketches.store(100, Ordering::Relaxed);
        m.batches.store(25, Ordering::Relaxed);
        let s = m.snapshot();
        assert!((s.mean_batch_fill - 4.0).abs() < 1e-12);
        assert!(s.uptime_s >= 0.0);
    }

    #[test]
    fn snapshot_to_json_parses_back() {
        let m = Metrics::default();
        m.sketch_latency.record(123);
        m.estimate_latency.record(7);
        let j = m.snapshot().to_json();
        let parsed = Json::parse(&j.to_string()).unwrap();
        assert_eq!(
            parsed
                .get("sketch_latency")
                .unwrap()
                .get("count")
                .unwrap()
                .as_u64()
                .unwrap(),
            1
        );
        assert_eq!(
            parsed
                .get("estimate_latency")
                .unwrap()
                .get("count")
                .unwrap()
                .as_u64()
                .unwrap(),
            1
        );
        let buckets = parsed
            .get("sketch_latency")
            .unwrap()
            .get("buckets")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(buckets.len(), BUCKETS);
        assert!(parsed.get("uptime_s").unwrap().as_f64().unwrap() >= 0.0);
    }
}
