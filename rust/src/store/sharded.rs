//! Sharded sketch index: N independent [`BandingIndex`] shards, each
//! behind its own `RwLock`, with inserts/deletes routed by a mix of
//! the item id and queries fanned out across shards on scoped threads.
//!
//! Sharding is a pure scaling knob, not a semantics change: results
//! are merged under the same total order (score desc, id asc) the
//! single-shard index uses, so `N = 1` is byte-identical to a bare
//! [`BandingIndex`] and `N > 1` returns exactly the same top-k set
//! (each shard's local top-k is a superset of its contribution to the
//! global top-k).

use crate::index::{sort_neighbors, BandingIndex, IndexConfig, Neighbor};
use crate::obs::{add_stage_us, capture_stages, sink_active, stage, Stage, NUM_STAGES};
use crate::sketch::{corrected_estimate, packed_words};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::RwLock;

/// SplitMix64 finalizer — decorrelates shard choice from id assignment
/// order so sequential ids spread evenly across shards.  Also the hash
/// behind the cluster client's rendezvous node routing, which needs
/// the same property one level up (spread keys evenly over nodes).
#[inline]
pub(crate) fn mix64(mut x: u64) -> u64 {
    x ^= x >> 30;
    x = x.wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^= x >> 31;
    x
}

/// Pick a shard count for `requested` (0 = auto): the largest power of
/// two ≤ the machine's available parallelism, capped at 8.
pub fn resolve_shards(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let mut s = 1usize;
    while s * 2 <= cores && s < 8 {
        s *= 2;
    }
    s
}

/// Below this many resident items, cross-shard queries run inline on
/// the calling thread instead of spawning per-shard threads.
const PARALLEL_QUERY_MIN_ITEMS: usize = 8192;

/// Point-in-time operation counts for one shard (`/stats`,
/// `cminhash_shard_ops_total`).  `queries` counts shard *probes*: a
/// batch of P probes against S shards adds P to every shard it
/// touches, so a hot shard shows up as a hot row, not an average.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ShardOps {
    /// Rows inserted into this shard (fresh-id, explicit-id, batched
    /// and packed ingest all count).
    pub inserts: u64,
    /// Rows removed from this shard.
    pub deletes: u64,
    /// Probe evaluations routed through this shard.
    pub queries: u64,
}

/// Live atomic mirror of [`ShardOps`], one per shard.
#[derive(Debug, Default)]
struct ShardCounters {
    inserts: AtomicU64,
    deletes: AtomicU64,
    queries: AtomicU64,
}

/// A sharded, concurrently accessible banding index over sketches.
///
/// Each shard owns its own [`BandingIndex`] (band postings + sketch
/// map) behind its own `RwLock`; writes touch exactly one shard,
/// reads fan out and merge.
#[derive(Debug)]
pub struct ShardedIndex {
    k: usize,
    cfg: IndexConfig,
    bits: u8,
    next_id: AtomicU64,
    // Resident-item count maintained on insert/delete so hot read
    // paths (len, the fan-out threshold, stats) never have to sweep
    // every shard lock.
    resident: AtomicUsize,
    shards: Vec<RwLock<BandingIndex>>,
    // One counter triple per shard, bumped with relaxed atomics so the
    // observability surface never contends with the data path.
    ops: Vec<ShardCounters>,
}

// Shard `RwLock`s poison only if an insert/query panicked holding the
// guard — the shard may hold a half-applied batch, so crash and let
// recovery rebuild.  The `.read()/.write().unwrap()` calls throughout
// this impl are that idiom (see clippy.toml); `join().expect` likewise
// surfaces worker panics rather than folding them into `Error`.
#[allow(clippy::disallowed_methods)]
impl ShardedIndex {
    /// Create a full-width index over sketches of length `k`,
    /// partitioned into `num_shards` (≥ 1) shards (equivalent to
    /// [`ShardedIndex::with_bits`] at `bits = 32`).
    pub fn new(k: usize, cfg: IndexConfig, num_shards: usize) -> crate::Result<Self> {
        Self::with_bits(k, cfg, 32, num_shards)
    }

    /// Create an index over sketches of length `k` storing `bits` bits
    /// per hash in every shard (32 = full width, smaller = packed
    /// bit-matrix rows scored by the popcount kernel).
    pub fn with_bits(
        k: usize,
        cfg: IndexConfig,
        bits: u8,
        num_shards: usize,
    ) -> crate::Result<Self> {
        if num_shards == 0 {
            return Err(crate::Error::Invalid("need at least one shard".into()));
        }
        let mut shards = Vec::with_capacity(num_shards);
        for _ in 0..num_shards {
            shards.push(RwLock::new(BandingIndex::with_bits(k, cfg, bits)?));
        }
        let ops = (0..num_shards).map(|_| ShardCounters::default()).collect();
        Ok(ShardedIndex {
            k,
            cfg,
            bits,
            next_id: AtomicU64::new(0),
            resident: AtomicUsize::new(0),
            shards,
            ops,
        })
    }

    /// Sketch length K.
    pub fn num_hashes(&self) -> usize {
        self.k
    }

    /// Bits stored per hash (32 = full width).
    pub fn bits(&self) -> u8 {
        self.bits
    }

    /// Resident bytes per stored sketch row (truthful across storage
    /// modes: K·4 full-width, one packed row of u64 words otherwise).
    pub fn sketch_bytes_per_item(&self) -> usize {
        if self.bits == 32 {
            self.k * 4
        } else {
            packed_words(self.k, self.bits) * 8
        }
    }

    /// Band configuration (shared by every shard).
    pub fn config(&self) -> IndexConfig {
        self.cfg
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    /// The next id a fresh [`ShardedIndex::insert`] would hand out.
    pub fn next_id(&self) -> u64 {
        self.next_id.load(Ordering::Relaxed)
    }

    /// Ensure every future fresh id is ≥ `floor` (snapshot recovery).
    pub fn reserve_ids(&self, floor: u64) {
        self.next_id.fetch_max(floor, Ordering::Relaxed);
    }

    #[inline]
    fn shard_of(&self, id: u64) -> usize {
        (mix64(id) % self.shards.len() as u64) as usize
    }

    fn check_len(&self, sketch: &[u32]) -> crate::Result<()> {
        if sketch.len() != self.k {
            return Err(crate::Error::ShapeMismatch {
                what: "sketch",
                expected: self.k,
                got: sketch.len(),
            });
        }
        Ok(())
    }

    /// Insert a sketch under a fresh id and return it.
    pub fn insert(&self, sketch: &[u32]) -> crate::Result<u64> {
        self.check_len(sketch)?;
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let shard = self.shard_of(id);
        self.shards[shard].write().unwrap().insert(id, sketch)?;
        self.resident.fetch_add(1, Ordering::Relaxed);
        self.ops[shard].inserts.fetch_add(1, Ordering::Relaxed);
        Ok(id)
    }

    /// Insert a whole batch of sketches under fresh ids, taking each
    /// shard's write lock **once per batch** instead of once per item.
    /// Returns the assigned ids in row order (always `base..base+n`
    /// consecutive).  All sketch lengths are validated before any row
    /// is inserted, so the batch is all-or-nothing.
    pub fn insert_many(&self, sketches: &[Vec<u32>]) -> crate::Result<Vec<u64>> {
        for sk in sketches {
            self.check_len(sk)?;
        }
        let n = sketches.len();
        let base = self.next_id.fetch_add(n as u64, Ordering::Relaxed);
        // Group rows by owning shard so each lock is taken exactly once.
        let mut by_shard: Vec<Vec<(u64, &[u32])>> = vec![Vec::new(); self.shards.len()];
        {
            let _span = stage(Stage::ShardRoute);
            for (row, sk) in sketches.iter().enumerate() {
                let id = base + row as u64;
                by_shard[self.shard_of(id)].push((id, sk.as_slice()));
            }
        }
        for (i, (shard, rows)) in self.shards.iter().zip(&by_shard).enumerate() {
            if rows.is_empty() {
                continue;
            }
            let mut guard = shard.write().unwrap();
            for &(id, sk) in rows {
                // Fresh ids cannot collide, and lengths were validated
                // above, so this insert is infallible here.
                guard.insert(id, sk)?;
            }
            self.ops[i].inserts.fetch_add(rows.len() as u64, Ordering::Relaxed);
        }
        self.resident.fetch_add(n, Ordering::Relaxed);
        Ok((base..base + n as u64).collect())
    }

    /// Insert a batch of *already-packed* rows under fresh ids — the
    /// binary wire's zero-copy ingest: each row is memcpy'd into its
    /// shard's arena with band signatures hashed off the packed bits,
    /// no per-lane unpack/repack.  Row widths are validated before any
    /// insert (all-or-nothing, like [`ShardedIndex::insert_many`]),
    /// each shard's write lock is taken once per batch, and ids come
    /// back consecutive in row order.
    pub fn insert_packed_many(&self, rows: &[Vec<u64>]) -> crate::Result<Vec<u64>> {
        let want = packed_words(self.k, self.bits);
        for row in rows {
            if row.len() != want {
                return Err(crate::Error::ShapeMismatch {
                    what: "packed row words",
                    expected: want,
                    got: row.len(),
                });
            }
        }
        let n = rows.len();
        let base = self.next_id.fetch_add(n as u64, Ordering::Relaxed);
        let mut by_shard: Vec<Vec<(u64, &[u64])>> = vec![Vec::new(); self.shards.len()];
        {
            let _span = stage(Stage::ShardRoute);
            for (row, words) in rows.iter().enumerate() {
                let id = base + row as u64;
                by_shard[self.shard_of(id)].push((id, words.as_slice()));
            }
        }
        for (i, (shard, rows)) in self.shards.iter().zip(&by_shard).enumerate() {
            if rows.is_empty() {
                continue;
            }
            let mut guard = shard.write().unwrap();
            for &(id, words) in rows {
                // Fresh ids cannot collide, and widths were validated
                // above, so this insert is infallible here.
                guard.insert_packed(id, words)?;
            }
            self.ops[i].inserts.fetch_add(rows.len() as u64, Ordering::Relaxed);
        }
        self.resident.fetch_add(n, Ordering::Relaxed);
        Ok((base..base + n as u64).collect())
    }

    /// Insert under a caller-chosen id (WAL replay, snapshot load,
    /// re-insert after delete).  Keeps the fresh-id counter ahead of
    /// every explicit id; rejects occupied ids.
    pub fn insert_with_id(&self, id: u64, sketch: &[u32]) -> crate::Result<()> {
        self.check_len(sketch)?;
        let shard = self.shard_of(id);
        self.shards[shard].write().unwrap().insert(id, sketch)?;
        self.resident.fetch_add(1, Ordering::Relaxed);
        self.ops[shard].inserts.fetch_add(1, Ordering::Relaxed);
        self.next_id.fetch_max(id.saturating_add(1), Ordering::Relaxed);
        Ok(())
    }

    /// Bulk-load `(id, sketch)` rows under caller-chosen ids — the
    /// snapshot-recovery fast path.  Semantically identical to calling
    /// [`ShardedIndex::insert_with_id`] once per row in input order,
    /// but each shard's write lock is taken exactly once, and above
    /// the fan-out threshold every shard rebuilds its band postings on
    /// its own scoped thread.  Rows are grouped by owning shard with
    /// input order preserved, and a shard's state depends only on its
    /// own insertion sequence, so the rebuilt index — postings, packed
    /// arena layout, counters — is identical to a serial load.
    ///
    /// All lengths are validated before any row lands.  A mid-load
    /// error (a duplicate id) can leave other shards already loaded;
    /// callers on the recovery path treat any error as fatal and
    /// discard the index, so no rollback is attempted.
    pub fn load_items(&self, items: &[(u64, Vec<u32>)]) -> crate::Result<()> {
        for (_, sk) in items {
            self.check_len(sk)?;
        }
        let mut by_shard: Vec<Vec<(u64, &[u32])>> = vec![Vec::new(); self.shards.len()];
        {
            let _span = stage(Stage::ShardRoute);
            for (id, sk) in items {
                by_shard[self.shard_of(*id)].push((*id, sk.as_slice()));
            }
        }
        let load_shard =
            |shard: &RwLock<BandingIndex>, rows: &[(u64, &[u32])]| -> crate::Result<()> {
                let mut guard = shard.write().unwrap();
                for &(id, sk) in rows {
                    guard.insert(id, sk)?;
                }
                Ok(())
            };
        if items.len() < PARALLEL_QUERY_MIN_ITEMS {
            for (shard, rows) in self.shards.iter().zip(&by_shard) {
                load_shard(shard, rows)?;
            }
        } else {
            let results: Vec<crate::Result<()>> = std::thread::scope(|s| {
                self.shards
                    .iter()
                    .zip(&by_shard)
                    .map(|(shard, rows)| s.spawn(move || load_shard(shard, rows)))
                    .collect::<Vec<_>>()
                    .into_iter()
                    .map(|h| h.join().expect("shard load thread panicked"))
                    .collect()
            });
            for r in results {
                r?;
            }
        }
        for (counters, rows) in self.ops.iter().zip(&by_shard) {
            if !rows.is_empty() {
                counters.inserts.fetch_add(rows.len() as u64, Ordering::Relaxed);
            }
        }
        self.resident.fetch_add(items.len(), Ordering::Relaxed);
        if let Some(max_id) = items.iter().map(|(id, _)| *id).max() {
            self.next_id
                .fetch_max(max_id.saturating_add(1), Ordering::Relaxed);
        }
        Ok(())
    }

    /// Delete an id, returning its sketch; unknown ids are an error.
    pub fn delete(&self, id: u64) -> crate::Result<Vec<u32>> {
        let shard = self.shard_of(id);
        let removed = self.shards[shard]
            .write()
            .unwrap()
            .remove(id)
            .ok_or_else(|| crate::Error::Invalid(format!("unknown id {id}")))?;
        self.resident.fetch_sub(1, Ordering::Relaxed);
        self.ops[shard].deletes.fetch_add(1, Ordering::Relaxed);
        Ok(removed)
    }

    /// Stored sketch for an id (cloned out of the owning shard;
    /// values are masked to the stored width in packed mode).
    pub fn sketch(&self, id: u64) -> Option<Vec<u32>> {
        self.shards[self.shard_of(id)].read().unwrap().sketch(id)
    }

    /// Estimate J between two stored ids.  In packed storage mode the
    /// stored rows only keep b bits per lane, so the raw collision
    /// fraction is fed through the unbiased b-bit correction; at
    /// `bits = 32` this is exactly the plain collision estimator.
    pub fn estimate(&self, a: u64, b: u64) -> crate::Result<f64> {
        let sa = self
            .sketch(a)
            .ok_or_else(|| crate::Error::Invalid(format!("unknown id {a}")))?;
        let sb = self
            .sketch(b)
            .ok_or_else(|| crate::Error::Invalid(format!("unknown id {b}")))?;
        let collisions = sa.iter().zip(&sb).filter(|(x, y)| x == y).count();
        Ok(corrected_estimate(collisions, self.k, self.bits))
    }

    /// Top-k neighbors of a query sketch across all shards.
    ///
    /// With one shard this runs inline; otherwise each shard is
    /// queried on its own scoped thread and the per-shard top-k lists
    /// are merged under the global order.
    pub fn query(&self, sketch: &[u32], topk: usize) -> crate::Result<Vec<Neighbor>> {
        self.check_len(sketch)?;
        self.note_probes(1);
        if self.shards.len() == 1 {
            return Ok(self.shards[0].read().unwrap().query(sketch, topk));
        }
        let mut merged = self.fan_out(|shard| shard.query(sketch, topk));
        let _span = stage(Stage::ShardRoute);
        sort_neighbors(&mut merged);
        merged.truncate(topk);
        Ok(merged)
    }

    /// Top-k neighbors for a whole batch of query sketches, taking
    /// each shard's read lock **once per batch**: every shard scores
    /// all rows under one lock acquisition, then the per-shard partial
    /// results are merged per row under the same global order the
    /// single-probe [`ShardedIndex::query`] uses — so each row of the
    /// result equals `query(&sketches[row], topk)` exactly.
    pub fn query_many(
        &self,
        sketches: &[Vec<u32>],
        topk: usize,
    ) -> crate::Result<Vec<Vec<Neighbor>>> {
        for sk in sketches {
            self.check_len(sk)?;
        }
        self.note_probes(sketches.len() as u64);
        if self.shards.len() == 1 {
            let guard = self.shards[0].read().unwrap();
            return Ok(sketches.iter().map(|sk| guard.query(sk, topk)).collect());
        }
        let per_shard = self.fan_out_with(|shard| {
            sketches
                .iter()
                .map(|sk| shard.query(sk, topk))
                .collect::<Vec<_>>()
        });
        let _span = stage(Stage::ShardRoute);
        let mut out: Vec<Vec<Neighbor>> = vec![Vec::new(); sketches.len()];
        for shard_rows in per_shard {
            for (row, hits) in shard_rows.into_iter().enumerate() {
                out[row].extend(hits);
            }
        }
        for merged in &mut out {
            sort_neighbors(merged);
            merged.truncate(topk);
        }
        Ok(out)
    }

    /// All neighbors with estimate ≥ `threshold`, across all shards.
    pub fn query_above(&self, sketch: &[u32], threshold: f64) -> crate::Result<Vec<Neighbor>> {
        self.check_len(sketch)?;
        self.note_probes(1);
        if self.shards.len() == 1 {
            return Ok(self.shards[0].read().unwrap().query_above(sketch, threshold));
        }
        let mut merged = self.fan_out(|shard| shard.query_above(sketch, threshold));
        let _span = stage(Stage::ShardRoute);
        sort_neighbors(&mut merged);
        Ok(merged)
    }

    /// Credit `n` probe evaluations to every shard (each probe is
    /// scored against each shard, inline or fanned out).
    #[inline]
    fn note_probes(&self, n: u64) {
        for c in &self.ops {
            c.queries.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Run `f` against every shard and concatenate.  The caller
    /// merges, so inline and threaded paths return identical results.
    fn fan_out(&self, f: impl Fn(&BandingIndex) -> Vec<Neighbor> + Sync) -> Vec<Neighbor> {
        self.fan_out_with(f).into_iter().flatten().collect()
    }

    /// Run `f` once per shard (under that shard's read lock) and
    /// return the per-shard results in shard order.  Small indexes run
    /// inline — per-shard probe work is then comparable to the cost of
    /// spawning a thread, so fan-out would only add overhead — while
    /// large indexes run all shards on scoped threads in parallel.
    ///
    /// When the calling thread is inside a traced request, each worker
    /// runs with its own span sink armed ([`capture_stages`]) and the
    /// stage breakdown of the **slowest** worker — the critical path
    /// the request actually waited on through the join — is credited
    /// back to the request.  Crediting exactly one worker keeps the
    /// stage sum ≤ the request's wall-clock total (summing all workers
    /// could exceed it; per-stage maxima across workers could too).
    fn fan_out_with<R: Send>(&self, f: impl Fn(&BandingIndex) -> R + Sync) -> Vec<R> {
        if self.len() < PARALLEL_QUERY_MIN_ITEMS {
            return self
                .shards
                .iter()
                .map(|shard| f(&shard.read().unwrap()))
                .collect();
        }
        let f = &f;
        let traced = sink_active();
        let results: Vec<(R, [u64; NUM_STAGES])> = std::thread::scope(|s| {
            let handles: Vec<_> = self
                .shards
                .iter()
                .map(|shard| {
                    s.spawn(move || {
                        let shard = shard.read().unwrap();
                        if traced {
                            capture_stages(|| f(&shard))
                        } else {
                            (f(&shard), [0u64; NUM_STAGES])
                        }
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard query thread panicked"))
                .collect()
        });
        if traced {
            let slowest = results
                .iter()
                .map(|(_, us)| us)
                .max_by_key(|us| us.iter().sum::<u64>());
            if let Some(us) = slowest {
                for (i, &v) in us.iter().enumerate() {
                    if v > 0 {
                        add_stage_us(Stage::ALL[i], v);
                    }
                }
            }
        }
        results.into_iter().map(|(r, _)| r).collect()
    }

    /// Total number of indexed items (lock-free counter).
    pub fn len(&self) -> usize {
        self.resident.load(Ordering::Relaxed)
    }

    /// True iff no items are indexed.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Items per shard (occupancy, for `/stats`).
    pub fn shard_sizes(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.read().unwrap().len()).collect()
    }

    /// Per-shard insert/delete/probe counts since construction.
    pub fn shard_ops(&self) -> Vec<ShardOps> {
        self.ops
            .iter()
            .map(|c| ShardOps {
                inserts: c.inserts.load(Ordering::Relaxed),
                deletes: c.deletes.load(Ordering::Relaxed),
                queries: c.queries.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Band-table occupancy across all shards: `(total occupied
    /// buckets, largest single posting list)`.
    pub fn band_stats(&self) -> (usize, usize) {
        let mut buckets = 0usize;
        let mut max = 0usize;
        for shard in &self.shards {
            let (b, m) = shard.read().unwrap().bucket_stats();
            buckets += b;
            max = max.max(m);
        }
        (buckets, max)
    }

    /// Total LSH candidates scored across all shards since
    /// construction (post-dedup, pre-top-k).
    pub fn candidates_collected(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().candidates_collected())
            .sum()
    }

    /// All `(id, sketch)` pairs, sorted by id (snapshotting, tests).
    pub fn items(&self) -> Vec<(u64, Vec<u32>)> {
        let mut out = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let guard = shard.read().unwrap();
            out.extend(guard.iter());
        }
        out.sort_unstable_by_key(|(id, _)| *id);
        out
    }

    /// All `(id, packed row words)` pairs sorted by id when in packed
    /// mode, `None` at full width — the snapshot path that copies rows
    /// as stored words instead of widening every lane (see
    /// [`BandingIndex::packed_items`]).
    pub fn packed_items(&self) -> Option<Vec<(u64, Vec<u64>)>> {
        if self.bits == 32 {
            return None;
        }
        let mut out = Vec::with_capacity(self.len());
        for shard in &self.shards {
            let guard = shard.read().unwrap();
            out.extend(guard.packed_items().expect("packed shards"));
        }
        out.sort_unstable_by_key(|(id, _)| *id);
        Some(out)
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::sketch::{estimate, CMinHasher, Sketcher};

    fn cfg() -> IndexConfig {
        IndexConfig {
            bands: 16,
            rows_per_band: 4,
        }
    }

    fn sketches(n: usize) -> Vec<Vec<u32>> {
        let h = CMinHasher::new(1024, 64, 5);
        (0..n)
            .map(|i| {
                let doc: Vec<u32> = (i as u32 * 7..i as u32 * 7 + 80).collect();
                h.sketch_sparse(&doc)
            })
            .collect()
    }

    #[test]
    fn fresh_ids_are_sequential_and_routed() {
        let idx = ShardedIndex::new(64, cfg(), 4).unwrap();
        for (i, sk) in sketches(12).iter().enumerate() {
            assert_eq!(idx.insert(sk).unwrap(), i as u64);
        }
        assert_eq!(idx.len(), 12);
        assert_eq!(idx.shard_sizes().iter().sum::<usize>(), 12);
        assert_eq!(idx.num_shards(), 4);
        // every id is retrievable through its owning shard
        for i in 0..12u64 {
            assert!(idx.sketch(i).is_some(), "id {i} lost in routing");
        }
        let ids: Vec<u64> = idx.items().iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, (0..12).collect::<Vec<u64>>());
    }

    #[test]
    fn delete_and_reinsert_across_shards() {
        let idx = ShardedIndex::new(64, cfg(), 4).unwrap();
        let sks = sketches(8);
        for sk in &sks {
            idx.insert(sk).unwrap();
        }
        let removed = idx.delete(3).unwrap();
        assert_eq!(removed, sks[3]);
        assert!(idx.delete(3).is_err(), "unknown id after delete");
        assert!(idx.sketch(3).is_none());
        assert_eq!(idx.len(), 7);
        // query never returns the deleted id
        let hits = idx.query(&sks[3], 8).unwrap();
        assert!(hits.iter().all(|n| n.id != 3));
        // re-insert under the same id, and fresh ids skip past it
        idx.insert_with_id(3, &sks[3]).unwrap();
        assert_eq!(idx.query(&sks[3], 1).unwrap()[0].id, 3);
        let fresh = idx.insert(&sks[0]).unwrap();
        assert_eq!(fresh, 8);
    }

    #[test]
    fn validates_sketch_length() {
        let idx = ShardedIndex::new(64, cfg(), 2).unwrap();
        assert!(idx.insert(&[0u32; 63]).is_err());
        assert!(idx.query(&[0u32; 1], 3).is_err());
        assert!(idx.query_above(&[0u32; 65], 0.5).is_err());
        assert!(ShardedIndex::new(64, cfg(), 0).is_err());
    }

    #[test]
    fn estimate_matches_direct() {
        let idx = ShardedIndex::new(64, cfg(), 4).unwrap();
        let sks = sketches(4);
        for sk in &sks {
            idx.insert(sk).unwrap();
        }
        assert_eq!(idx.estimate(0, 1).unwrap(), estimate(&sks[0], &sks[1]));
        assert!(idx.estimate(0, 99).is_err());
    }

    #[test]
    fn parallel_fan_out_matches_inline_results() {
        // Push past PARALLEL_QUERY_MIN_ITEMS with cheap synthetic
        // sketches so the scoped-thread path actually runs, and pin it
        // against a single BandingIndex over the same items.
        let cfg = IndexConfig {
            bands: 4,
            rows_per_band: 2,
        };
        let n = PARALLEL_QUERY_MIN_ITEMS + 64;
        let sharded = ShardedIndex::new(8, cfg, 4).unwrap();
        let mut golden = BandingIndex::new(8, cfg).unwrap();
        for i in 0..n as u32 {
            // small value range -> real band collisions
            let sk: Vec<u32> = (0..8u32).map(|j| (i / 16).wrapping_add(j) % 97).collect();
            golden.insert(u64::from(i), &sk).unwrap();
            sharded.insert(&sk).unwrap();
        }
        assert!(sharded.len() >= PARALLEL_QUERY_MIN_ITEMS);
        for probe_seed in [0u32, 40, 800] {
            let probe: Vec<u32> = (0..8u32)
                .map(|j| (probe_seed / 16).wrapping_add(j) % 97)
                .collect();
            assert_eq!(
                sharded.query(&probe, 9).unwrap(),
                golden.query(&probe, 9),
                "parallel fan-out diverged for probe {probe_seed}"
            );
        }
    }

    #[test]
    fn parallel_fan_out_credits_worker_stages() {
        // Regression: queries that fan out across scoped worker threads
        // used to lose their BandLookup/Score spans (the workers'
        // thread-local sinks were never armed).  A traced request over
        // a large index must now see nonzero band/score attribution
        // while the stage sum stays within the request total.
        use crate::obs::{Obs, OpKind};
        use std::time::Instant;
        let cfg = IndexConfig {
            bands: 4,
            rows_per_band: 2,
        };
        let n = PARALLEL_QUERY_MIN_ITEMS + 64;
        let idx = ShardedIndex::new(8, cfg, 4).unwrap();
        for i in 0..n as u32 {
            let sk: Vec<u32> = (0..8u32).map(|j| (i / 16).wrapping_add(j) % 97).collect();
            idx.insert(&sk).unwrap();
        }
        let probes: Vec<Vec<u32>> = (0..64u32)
            .map(|p| (0..8u32).map(|j| (p / 4).wrapping_add(j) % 97).collect())
            .collect();
        let obs = Obs::new(8, u64::MAX, 0);
        let mut g = obs.begin_at(OpKind::QueryBatch, Instant::now());
        idx.query_many(&probes, 5).unwrap();
        g.finish(probes.len() as u32);
        let t = &obs.recent(1)[0];
        let band = t.stages_us[Stage::BandLookup as usize];
        let score = t.stages_us[Stage::Score as usize];
        assert!(
            band + score > 0,
            "fanned-out band/score work must attribute to stages, got {:?}",
            t.stages_us
        );
        assert!(
            t.stages_us.iter().sum::<u64>() <= t.total_us,
            "stage sum {} exceeds request total {}",
            t.stages_us.iter().sum::<u64>(),
            t.total_us
        );
    }

    #[test]
    fn load_items_matches_serial_insert_with_id() {
        // The bulk loader must rebuild byte-identical state on both
        // sides of the parallel threshold: same items, same counters,
        // same fresh-id floor, same query results.
        let cfg = IndexConfig {
            bands: 4,
            rows_per_band: 2,
        };
        for n in [64usize, PARALLEL_QUERY_MIN_ITEMS + 64] {
            let items: Vec<(u64, Vec<u32>)> = (0..n as u32)
                .map(|i| {
                    let sk: Vec<u32> =
                        (0..8u32).map(|j| (i / 16).wrapping_add(j) % 97).collect();
                    // non-contiguous ids so next_id tracking is exercised
                    (u64::from(i) * 3 + 1, sk)
                })
                .collect();
            let bulk = ShardedIndex::new(8, cfg, 4).unwrap();
            let serial = ShardedIndex::new(8, cfg, 4).unwrap();
            bulk.load_items(&items).unwrap();
            for (id, sk) in &items {
                serial.insert_with_id(*id, sk).unwrap();
            }
            assert_eq!(bulk.items(), serial.items(), "n={n}");
            assert_eq!(bulk.len(), serial.len(), "n={n}");
            assert_eq!(bulk.next_id(), serial.next_id(), "n={n}");
            assert_eq!(bulk.shard_ops(), serial.shard_ops(), "n={n}");
            let probe: Vec<u32> = (0..8u32).map(|j| j % 97).collect();
            assert_eq!(
                bulk.query(&probe, 7).unwrap(),
                serial.query(&probe, 7).unwrap(),
                "n={n}"
            );
        }
        // length validation rejects the whole batch up front
        let idx = ShardedIndex::new(8, cfg, 4).unwrap();
        assert!(idx
            .load_items(&[(0, vec![0u32; 8]), (1, vec![0u32; 7])])
            .is_err());
        assert!(idx.is_empty(), "nothing lands when validation fails");
    }

    #[test]
    fn insert_many_matches_singleton_inserts() {
        let sks = sketches(17);
        let batched = ShardedIndex::new(64, cfg(), 4).unwrap();
        let single = ShardedIndex::new(64, cfg(), 4).unwrap();
        let ids = batched.insert_many(&sks).unwrap();
        assert_eq!(ids, (0..17).collect::<Vec<u64>>(), "ids are consecutive");
        for sk in &sks {
            single.insert(sk).unwrap();
        }
        assert_eq!(batched.items(), single.items(), "same routing, same state");
        // fresh singleton ids continue past the batch
        assert_eq!(batched.insert(&sks[0]).unwrap(), 17);
        // a bad row poisons the whole batch before any insert happens
        let mixed = vec![sks[0].clone(), vec![0u32; 63]];
        assert!(batched.insert_many(&mixed).is_err());
        assert_eq!(batched.len(), 18, "all-or-nothing: nothing inserted");
    }

    #[test]
    fn insert_packed_many_matches_insert_many() {
        use crate::sketch::pack_row;
        let sks = sketches(17);
        for bits in [8u8, 32] {
            let via_lanes = ShardedIndex::with_bits(64, cfg(), bits, 4).unwrap();
            let via_words = ShardedIndex::with_bits(64, cfg(), bits, 4).unwrap();
            via_lanes.insert_many(&sks).unwrap();
            let packed: Vec<Vec<u64>> = sks
                .iter()
                .map(|sk| {
                    let mut row = vec![0u64; packed_words(64, bits)];
                    pack_row(sk, bits, &mut row);
                    row
                })
                .collect();
            let ids = via_words.insert_packed_many(&packed).unwrap();
            assert_eq!(ids, (0..17).collect::<Vec<u64>>(), "bits={bits}");
            assert_eq!(via_words.items(), via_lanes.items(), "bits={bits}");
            // queries agree end to end
            for probe in sks.iter().take(4) {
                assert_eq!(
                    via_words.query(probe, 5).unwrap(),
                    via_lanes.query(probe, 5).unwrap(),
                    "bits={bits}"
                );
            }
            // a bad row width poisons the whole batch up front
            let mixed = vec![packed[0].clone(), vec![0u64; packed[0].len() + 1]];
            assert!(via_words.insert_packed_many(&mixed).is_err());
            assert_eq!(via_words.len(), 17, "bits={bits}: all-or-nothing");
        }
    }

    #[test]
    fn query_many_matches_per_probe_queries() {
        let idx = ShardedIndex::new(64, cfg(), 4).unwrap();
        let sks = sketches(40);
        idx.insert_many(&sks).unwrap();
        let probes: Vec<Vec<u32>> = sks.iter().take(6).cloned().collect();
        let batched = idx.query_many(&probes, 5).unwrap();
        assert_eq!(batched.len(), 6);
        for (row, probe) in probes.iter().enumerate() {
            assert_eq!(
                batched[row],
                idx.query(probe, 5).unwrap(),
                "row {row} diverged from the singleton query"
            );
        }
        // length validation covers every row
        assert!(idx.query_many(&[vec![0u32; 63]], 5).is_err());
    }

    #[test]
    fn packed_shards_route_query_and_estimate_like_full_width() {
        // The packed plane through the sharded layer: same routing,
        // self-probes exact, estimates corrected, memory accounting
        // truthful.
        let full = ShardedIndex::new(64, cfg(), 4).unwrap();
        let packed = ShardedIndex::with_bits(64, cfg(), 8, 4).unwrap();
        assert_eq!(packed.bits(), 8);
        assert_eq!(packed.sketch_bytes_per_item(), 64);
        assert_eq!(full.sketch_bytes_per_item(), 256);
        let sks = sketches(12);
        for sk in &sks {
            full.insert(sk).unwrap();
            packed.insert(sk).unwrap();
        }
        for (i, sk) in sks.iter().enumerate() {
            let hits = packed.query(sk, 1).unwrap();
            assert_eq!(hits[0].id, i as u64, "self probe row {i}");
            assert_eq!(hits[0].score, 1.0);
        }
        // self-estimate is exactly 1 even after the b-bit correction
        assert_eq!(packed.estimate(3, 3).unwrap(), 1.0);
        // cross estimates stay probabilities
        let jhat = packed.estimate(0, 1).unwrap();
        assert!((0.0..=1.0).contains(&jhat));
        // delete + reinsert keeps working through the packed shards
        let removed = packed.delete(3).unwrap();
        assert_eq!(removed, sks[3].iter().map(|&v| v & 0xff).collect::<Vec<u32>>());
        assert!(packed.query(&sks[3], 8).unwrap().iter().all(|n| n.id != 3));
        packed.insert_with_id(3, &sks[3]).unwrap();
        assert_eq!(packed.query(&sks[3], 1).unwrap()[0].id, 3);
    }

    #[test]
    fn shard_ops_and_band_stats_track_activity() {
        let idx = ShardedIndex::new(64, cfg(), 4).unwrap();
        let sks = sketches(10);
        idx.insert_many(&sks[..8]).unwrap();
        idx.insert(&sks[8]).unwrap();
        idx.insert_with_id(100, &sks[9]).unwrap();
        idx.delete(100).unwrap();
        idx.query(&sks[0], 3).unwrap();
        idx.query_many(&sks[..3], 3).unwrap();
        idx.query_above(&sks[1], 0.5).unwrap();
        let ops = idx.shard_ops();
        assert_eq!(ops.len(), 4);
        assert_eq!(ops.iter().map(|o| o.inserts).sum::<u64>(), 10);
        assert_eq!(ops.iter().map(|o| o.deletes).sum::<u64>(), 1);
        // every probe touches every shard: 1 + 3 + 1 each
        for (i, o) in ops.iter().enumerate() {
            assert_eq!(o.queries, 5, "shard {i}");
        }
        // aggregates are consistent with per-shard reality
        let (buckets, max) = idx.band_stats();
        assert!(buckets > 0 && max >= 1);
        assert!(idx.candidates_collected() >= 1, "self-probes hit");
    }

    #[test]
    fn resolve_shards_is_sane() {
        assert_eq!(resolve_shards(3), 3);
        let auto = resolve_shards(0);
        assert!((1..=8).contains(&auto));
        assert!(auto.is_power_of_two());
    }
}
