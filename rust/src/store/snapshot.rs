//! Compact binary snapshot of the whole sketch store.
//!
//! ```text
//! snapshot  := v2 | v3
//! v2        := magic "CMHSNAP2" | k:u32le | scheme:u32le | next_id:u64le
//!            | count:u64le | count × (id:u64le | k × u32le)
//!            | crc:u64le                    (FNV-1a 64 over all prior bytes)
//! v3        := magic "CMHSNAP3" | k:u32le | scheme:u32le | bits:u32le
//!            | next_id:u64le | count:u64le
//!            | count × (id:u64le | W × u64le)   W = ceil(K·bits / 64)
//!            | crc:u64le
//! ```
//!
//! Written to a temp file, fsynced, then renamed into place, so a
//! crash during [`Snapshot::write`] leaves the previous snapshot
//! intact.  Items are sorted by id, so identical store contents
//! produce identical snapshot bytes.
//!
//! **Versioning / migration.**  `CMHSNAP2` added the `scheme` field
//! (the [`SketchScheme`] code) so a store built under one hashing
//! scheme refuses to load under another.  `CMHSNAP3` adds the sketch
//! width: packed stores (`sketch.bits` < 32) persist their rows as
//! the same bit-packed words they serve from, shrinking the snapshot
//! by ≈ 32/b×.  A full-width store (`bits = 32`) still writes
//! byte-identical `CMHSNAP2` images — the on-disk format only changes
//! when the storage mode does.  Legacy `CMHSNAP1` (no scheme) and
//! `CMHSNAP2` (no width) snapshots load as `scheme = cmh` /
//! `bits = 32` respectively; a packed store refuses them (mismatched
//! width) with an error naming both widths, same as the scheme stamp.

use crate::sketch::{pack_row, packed_words, unpack_row, SketchScheme};
use crate::util::fnv::fnv1a64;
use std::io::Write;
use std::path::Path;

const MAGIC_V3: &[u8; 8] = b"CMHSNAP3";
const MAGIC_V2: &[u8; 8] = b"CMHSNAP2";
const MAGIC_V1: &[u8; 8] = b"CMHSNAP1";

fn bad(msg: impl Into<String>) -> crate::Error {
    crate::Error::Invalid(format!("snapshot: {}", msg.into()))
}

/// Decoded snapshot contents.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotData {
    /// Sketch length K the snapshot was taken under.
    pub k: usize,
    /// Hashing scheme the sketches were produced by (`cmh` for legacy
    /// v1 snapshots, which predate scheme selection).
    pub scheme: SketchScheme,
    /// Bits stored per hash (32 for v1/v2 snapshots, which predate
    /// packed storage).
    pub bits: u8,
    /// Fresh-id floor at snapshot time.
    pub next_id: u64,
    /// All `(id, sketch)` pairs, sorted by id (values masked to
    /// `bits` in packed snapshots).
    pub items: Vec<(u64, Vec<u32>)>,
}

/// Snapshot codec (see the module docs for the byte format).
pub struct Snapshot;

/// The shared header prefix (`magic … count`) of both formats.
fn header(k: usize, scheme: SketchScheme, bits: u8, next_id: u64, count: usize) -> Vec<u8> {
    let mut buf = Vec::new();
    if bits != 32 {
        buf.extend_from_slice(MAGIC_V3);
        buf.extend_from_slice(&(k as u32).to_le_bytes());
        buf.extend_from_slice(&scheme.code().to_le_bytes());
        buf.extend_from_slice(&u32::from(bits).to_le_bytes());
    } else {
        buf.extend_from_slice(MAGIC_V2);
        buf.extend_from_slice(&(k as u32).to_le_bytes());
        buf.extend_from_slice(&scheme.code().to_le_bytes());
    }
    buf.extend_from_slice(&next_id.to_le_bytes());
    buf.extend_from_slice(&(count as u64).to_le_bytes());
    buf
}

/// Append the trailing checksum and land `buf` at `path` atomically
/// (temp file + fsync + rename + directory fsync).
fn finish(path: &Path, mut buf: Vec<u8>) -> crate::Result<u64> {
    let crc = fnv1a64(&buf);
    buf.extend_from_slice(&crc.to_le_bytes());

    let tmp = path.with_extension("tmp");
    {
        let mut f = std::fs::File::create(&tmp)?;
        f.write_all(&buf)?;
        f.sync_all()?;
    }
    std::fs::rename(&tmp, path)?;
    // The rename itself is directory metadata: fsync the directory
    // so the new snapshot is durable before the caller truncates
    // the WAL — otherwise power loss could keep the truncation but
    // drop the rename, losing every folded record.
    #[cfg(unix)]
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::File::open(parent)?.sync_all()?;
    }
    Ok(buf.len() as u64)
}

impl Snapshot {
    /// Serialize `items` (each sketch of length `k`, produced by
    /// `scheme`, stored at `bits` per hash) to `path` atomically
    /// (temp file + fsync + rename).  `bits = 32` emits the v2
    /// format byte-for-byte; narrower widths emit v3 with bit-packed
    /// rows.  Returns the snapshot size in bytes.
    pub fn write(
        path: &Path,
        k: usize,
        scheme: SketchScheme,
        bits: u8,
        next_id: u64,
        items: &[(u64, Vec<u32>)],
    ) -> crate::Result<u64> {
        let packed = bits != 32;
        let wpr = packed_words(k, bits);
        let row_bytes = if packed { 8 * wpr } else { 4 * k };
        let mut buf = header(k, scheme, bits, next_id, items.len());
        buf.reserve(items.len() * (8 + row_bytes) + 8);
        let mut row = vec![0u64; wpr];
        for (id, sketch) in items {
            if sketch.len() != k {
                return Err(bad(format!(
                    "id {id} has sketch length {}, expected {k}",
                    sketch.len()
                )));
            }
            buf.extend_from_slice(&id.to_le_bytes());
            if packed {
                pack_row(sketch, bits, &mut row);
                for w in &row {
                    buf.extend_from_slice(&w.to_le_bytes());
                }
            } else {
                for v in sketch {
                    buf.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
        finish(path, buf)
    }

    /// [`Snapshot::write`] for rows that are *already* bit-packed
    /// (`bits` < 32 only): emits byte-identical `CMHSNAP3` images
    /// without widening a single lane — the compaction path of a
    /// packed store, whose transient memory stays proportional to the
    /// packed footprint instead of 32/b× larger.
    pub fn write_packed(
        path: &Path,
        k: usize,
        scheme: SketchScheme,
        bits: u8,
        next_id: u64,
        items: &[(u64, Vec<u64>)],
    ) -> crate::Result<u64> {
        if bits == 32 {
            return Err(bad("write_packed needs a packed width (bits < 32)"));
        }
        let wpr = packed_words(k, bits);
        let mut buf = header(k, scheme, bits, next_id, items.len());
        buf.reserve(items.len() * (8 + 8 * wpr) + 8);
        for (id, row) in items {
            if row.len() != wpr {
                return Err(bad(format!(
                    "id {id} has {} packed words, K={k} at bits={bits} needs {wpr}",
                    row.len()
                )));
            }
            buf.extend_from_slice(&id.to_le_bytes());
            for w in row {
                buf.extend_from_slice(&w.to_le_bytes());
            }
        }
        finish(path, buf)
    }

    /// Load and validate a snapshot (magic, checksum, exact framing).
    /// Accepts the current `CMHSNAP3` packed format, full-width
    /// `CMHSNAP2`, and legacy `CMHSNAP1` (no scheme field; decoded as
    /// `cmh` — see the module docs).
    // Every `try_into().unwrap()` below converts a slice whose length
    // was just checked against the framing — the fallible path is the
    // explicit length/checksum validation, not the conversion.
    #[allow(clippy::disallowed_methods)]
    pub fn load(path: &Path) -> crate::Result<SnapshotData> {
        let bytes = std::fs::read(path)?;
        if bytes.len() < 8 + 8 {
            return Err(bad("file too short"));
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 8);
        let mut crc = [0u8; 8];
        crc.copy_from_slice(crc_bytes);
        if fnv1a64(body) != u64::from_le_bytes(crc) {
            return Err(bad("checksum mismatch"));
        }
        let magic: &[u8] = &body[..8];
        // Bytes between the scheme field (if any) and next_id.
        let (version, extra_fields) = if magic == MAGIC_V3 {
            (3u32, 8usize) // scheme + bits
        } else if magic == MAGIC_V2 {
            (2u32, 4usize) // scheme
        } else if magic == MAGIC_V1 {
            (1u32, 0usize)
        } else {
            return Err(bad("bad magic"));
        };
        let header = 8 + 4 + extra_fields + 8 + 8;
        if body.len() < header {
            return Err(bad("file too short"));
        }
        let k = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
        let scheme = if version >= 2 {
            let code = u32::from_le_bytes(body[12..16].try_into().unwrap());
            SketchScheme::from_code(code)?
        } else {
            SketchScheme::Cmh
        };
        let bits = if version >= 3 {
            let raw = u32::from_le_bytes(body[16..20].try_into().unwrap());
            let bits = u8::try_from(raw)
                .map_err(|_| bad(format!("bad bits field {raw}")))?;
            crate::sketch::check_sketch_bits(bits).map_err(|e| bad(e.to_string()))?;
            bits
        } else {
            32
        };
        let off0 = 12 + extra_fields;
        let next_id = u64::from_le_bytes(body[off0..off0 + 8].try_into().unwrap());
        let count =
            u64::from_le_bytes(body[off0 + 8..off0 + 16].try_into().unwrap()) as usize;
        let packed = version >= 3 && bits != 32;
        let wpr = packed_words(k, bits);
        let row_bytes = if packed { 8 * wpr } else { 4 * k };
        let item_bytes = count
            .checked_mul(8 + row_bytes)
            .ok_or_else(|| bad("count overflow"))?;
        if body.len() - header != item_bytes {
            return Err(bad(format!(
                "expected {item_bytes} item bytes, found {}",
                body.len() - header
            )));
        }
        let mut items = Vec::with_capacity(count);
        let mut off = header;
        let mut row = vec![0u64; wpr];
        for _ in 0..count {
            let id = u64::from_le_bytes(body[off..off + 8].try_into().unwrap());
            off += 8;
            let sketch = if packed {
                for w in row.iter_mut() {
                    *w = u64::from_le_bytes(body[off..off + 8].try_into().unwrap());
                    off += 8;
                }
                unpack_row(&row, k, bits)
            } else {
                let mut sketch = Vec::with_capacity(k);
                for _ in 0..k {
                    sketch.push(u32::from_le_bytes(
                        body[off..off + 4].try_into().unwrap(),
                    ));
                    off += 4;
                }
                sketch
            };
            items.push((id, sketch));
        }
        Ok(SnapshotData {
            k,
            scheme,
            bits,
            next_id,
            items,
        })
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;

    fn sample_items() -> Vec<(u64, Vec<u32>)> {
        vec![
            (0, vec![5, 6, 7]),
            (2, vec![1, 2, 3]),
            (9, vec![u32::MAX, 0, 42]),
        ]
    }

    #[test]
    fn write_load_roundtrip() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        let bytes =
            Snapshot::write(&path, 3, SketchScheme::Cmh, 32, 10, &sample_items())
                .unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        let data = Snapshot::load(&path).unwrap();
        assert_eq!(data.k, 3);
        assert_eq!(data.scheme, SketchScheme::Cmh);
        assert_eq!(data.bits, 32);
        assert_eq!(data.next_id, 10);
        assert_eq!(data.items, sample_items());
    }

    #[test]
    fn full_width_snapshots_stay_byte_identical_v2() {
        // bits = 32 must keep emitting exactly the pre-b-bit CMHSNAP2
        // image: hand-roll it and compare whole files.
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        let k = 3usize;
        let items = sample_items();
        Snapshot::write(&path, k, SketchScheme::Oph, 32, 7, &items).unwrap();
        let mut expect = Vec::new();
        expect.extend_from_slice(b"CMHSNAP2");
        expect.extend_from_slice(&(k as u32).to_le_bytes());
        expect.extend_from_slice(&SketchScheme::Oph.code().to_le_bytes());
        expect.extend_from_slice(&7u64.to_le_bytes());
        expect.extend_from_slice(&(items.len() as u64).to_le_bytes());
        for (id, sketch) in &items {
            expect.extend_from_slice(&id.to_le_bytes());
            for v in sketch {
                expect.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = crate::util::fnv::fnv1a64(&expect);
        expect.extend_from_slice(&crc.to_le_bytes());
        assert_eq!(std::fs::read(&path).unwrap(), expect);
    }

    #[test]
    fn packed_snapshots_roundtrip_and_shrink() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        // K = 100 at b = 4 → 400 bits → 7 words/row (partial last word)
        let k = 100usize;
        let items: Vec<(u64, Vec<u32>)> = (0..5u64)
            .map(|id| {
                (
                    id * 3,
                    (0..k as u32).map(|i| (id as u32 * 31 + i * 7) % 16).collect(),
                )
            })
            .collect();
        for bits in [1u8, 2, 4, 8, 16] {
            let bytes =
                Snapshot::write(&path, k, SketchScheme::Coph, bits, 40, &items)
                    .unwrap();
            let data = Snapshot::load(&path).unwrap();
            assert_eq!(data.bits, bits);
            assert_eq!(data.k, k);
            assert_eq!(data.scheme, SketchScheme::Coph);
            assert_eq!(data.next_id, 40);
            // values < 16 survive every width ≥ 4 exactly; narrower
            // widths keep the masked lanes
            let mask = (1u32 << bits) - 1;
            for ((id, got), (want_id, want)) in data.items.iter().zip(&items) {
                assert_eq!(id, want_id);
                let masked: Vec<u32> = want.iter().map(|&v| v & mask).collect();
                assert_eq!(got, &masked, "bits={bits}");
            }
            // packed rows shrink the image vs full width
            let full =
                Snapshot::write(&path, k, SketchScheme::Coph, 32, 40, &items)
                    .unwrap();
            assert!(bytes < full, "bits={bits}: {bytes} !< {full}");
        }
    }

    #[test]
    fn write_packed_is_byte_identical_to_write() {
        // The words-level compaction path must emit exactly the bytes
        // the lane-level path does — one format, two producers.
        let dir = TempDir::new().unwrap();
        let a = dir.path().join("a.bin");
        let b = dir.path().join("b.bin");
        let k = 37usize;
        let bits = 4u8;
        let items: Vec<(u64, Vec<u32>)> = (0..4u64)
            .map(|id| (id * 2, (0..k as u32).map(|i| (i + id as u32) % 16).collect()))
            .collect();
        let packed: Vec<(u64, Vec<u64>)> = items
            .iter()
            .map(|(id, sk)| {
                let mut row = vec![0u64; crate::sketch::packed_words(k, bits)];
                pack_row(sk, bits, &mut row);
                (*id, row)
            })
            .collect();
        Snapshot::write(&a, k, SketchScheme::Cmh, bits, 9, &items).unwrap();
        Snapshot::write_packed(&b, k, SketchScheme::Cmh, bits, 9, &packed).unwrap();
        assert_eq!(std::fs::read(&a).unwrap(), std::fs::read(&b).unwrap());
        // and it validates its inputs
        assert!(
            Snapshot::write_packed(&b, k, SketchScheme::Cmh, 32, 9, &packed).is_err(),
            "full width has no packed rows"
        );
        assert!(Snapshot::write_packed(
            &b,
            k,
            SketchScheme::Cmh,
            8,
            9,
            &packed
        )
        .is_err(), "word count must match the width");
    }

    #[test]
    fn every_scheme_roundtrips_through_the_header() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        for scheme in SketchScheme::ALL {
            Snapshot::write(&path, 3, scheme, 32, 7, &sample_items()).unwrap();
            assert_eq!(Snapshot::load(&path).unwrap().scheme, scheme);
        }
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        Snapshot::write(&path, 64, SketchScheme::Coph, 32, 0, &[]).unwrap();
        let data = Snapshot::load(&path).unwrap();
        assert!(data.items.is_empty());
        assert_eq!(data.k, 64);
        assert_eq!(data.scheme, SketchScheme::Coph);
        // an empty packed stamp also roundtrips, carrying its width
        Snapshot::write(&path, 64, SketchScheme::Cmh, 8, 0, &[]).unwrap();
        let data = Snapshot::load(&path).unwrap();
        assert!(data.items.is_empty());
        assert_eq!(data.bits, 8);
    }

    #[test]
    fn legacy_v1_snapshot_loads_as_cmh() {
        // Hand-roll a CMHSNAP1 image (the pre-scheme format): the
        // migration contract is that it decodes with scheme = cmh and
        // bits = 32.
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        let k = 3usize;
        let items = sample_items();
        let mut buf = Vec::new();
        buf.extend_from_slice(b"CMHSNAP1");
        buf.extend_from_slice(&(k as u32).to_le_bytes());
        buf.extend_from_slice(&10u64.to_le_bytes());
        buf.extend_from_slice(&(items.len() as u64).to_le_bytes());
        for (id, sketch) in &items {
            buf.extend_from_slice(&id.to_le_bytes());
            for v in sketch {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = crate::util::fnv::fnv1a64(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &buf).unwrap();

        let data = Snapshot::load(&path).unwrap();
        assert_eq!(data.scheme, SketchScheme::Cmh, "v1 predates schemes");
        assert_eq!(data.bits, 32, "v1 predates packed storage");
        assert_eq!(data.k, k);
        assert_eq!(data.next_id, 10);
        assert_eq!(data.items, items);
    }

    #[test]
    fn rewrite_is_atomic_replacement() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        Snapshot::write(&path, 3, SketchScheme::Cmh, 32, 5, &sample_items()).unwrap();
        Snapshot::write(&path, 3, SketchScheme::Cmh, 32, 6, &sample_items()[..1])
            .unwrap();
        let data = Snapshot::load(&path).unwrap();
        assert_eq!(data.next_id, 6);
        assert_eq!(data.items.len(), 1);
        assert!(!path.with_extension("tmp").exists(), "tmp cleaned up");
    }

    #[test]
    fn corruption_is_detected() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        for bits in [32u8, 4] {
            Snapshot::write(&path, 3, SketchScheme::Cmh, bits, 10, &sample_items())
                .unwrap();
            let mut bytes = std::fs::read(&path).unwrap();
            bytes[30] ^= 0x01;
            std::fs::write(&path, &bytes).unwrap();
            assert!(
                Snapshot::load(&path).is_err(),
                "bits={bits}: checksum must catch flips"
            );
            // truncation is also caught
            let good = {
                Snapshot::write(&path, 3, SketchScheme::Cmh, bits, 10, &sample_items())
                    .unwrap();
                std::fs::read(&path).unwrap()
            };
            std::fs::write(&path, &good[..good.len() - 3]).unwrap();
            assert!(Snapshot::load(&path).is_err(), "bits={bits}");
        }
        // wrong-length sketches are rejected at write time
        assert!(
            Snapshot::write(&path, 4, SketchScheme::Cmh, 32, 0, &sample_items())
                .is_err()
        );
    }
}
