//! Compact binary snapshot of the whole sketch store.
//!
//! ```text
//! snapshot := magic "CMHSNAP2" | k:u32le | scheme:u32le | next_id:u64le
//!           | count:u64le | count × (id:u64le | k × u32le)
//!           | crc:u64le                     (FNV-1a 64 over all prior bytes)
//! ```
//!
//! Written to a temp file, fsynced, then renamed into place, so a
//! crash during [`Snapshot::write`] leaves the previous snapshot
//! intact.  Items are sorted by id, so identical store contents
//! produce identical snapshot bytes.
//!
//! **Versioning / migration.**  `CMHSNAP2` added the `scheme` field
//! (the [`SketchScheme`] code) so a store built under one hashing
//! scheme refuses to load under another — sketches from different
//! schemes are incomparable bytes, and silently mixing them would
//! corrupt every estimate.  Legacy `CMHSNAP1` snapshots (which predate
//! scheme selection and were only ever produced by the `cmh` scheme)
//! still load, reporting `scheme = cmh`; the next compaction rewrites
//! them as `CMHSNAP2`.

use crate::sketch::SketchScheme;
use crate::util::fnv::fnv1a64;
use std::io::Write;
use std::path::Path;

const MAGIC_V2: &[u8; 8] = b"CMHSNAP2";
const MAGIC_V1: &[u8; 8] = b"CMHSNAP1";

fn bad(msg: impl Into<String>) -> crate::Error {
    crate::Error::Invalid(format!("snapshot: {}", msg.into()))
}

/// Decoded snapshot contents.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SnapshotData {
    /// Sketch length K the snapshot was taken under.
    pub k: usize,
    /// Hashing scheme the sketches were produced by (`cmh` for legacy
    /// v1 snapshots, which predate scheme selection).
    pub scheme: SketchScheme,
    /// Fresh-id floor at snapshot time.
    pub next_id: u64,
    /// All `(id, sketch)` pairs, sorted by id.
    pub items: Vec<(u64, Vec<u32>)>,
}

/// Snapshot codec (see the module docs for the byte format).
pub struct Snapshot;

impl Snapshot {
    /// Serialize `items` (each sketch of length `k`, produced by
    /// `scheme`) to `path` atomically (temp file + fsync + rename).
    /// Returns the snapshot size in bytes.
    pub fn write(
        path: &Path,
        k: usize,
        scheme: SketchScheme,
        next_id: u64,
        items: &[(u64, Vec<u32>)],
    ) -> crate::Result<u64> {
        let mut buf =
            Vec::with_capacity(8 + 4 + 4 + 8 + 8 + items.len() * (8 + 4 * k) + 8);
        buf.extend_from_slice(MAGIC_V2);
        buf.extend_from_slice(&(k as u32).to_le_bytes());
        buf.extend_from_slice(&scheme.code().to_le_bytes());
        buf.extend_from_slice(&next_id.to_le_bytes());
        buf.extend_from_slice(&(items.len() as u64).to_le_bytes());
        for (id, sketch) in items {
            if sketch.len() != k {
                return Err(bad(format!(
                    "id {id} has sketch length {}, expected {k}",
                    sketch.len()
                )));
            }
            buf.extend_from_slice(&id.to_le_bytes());
            for v in sketch {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = fnv1a64(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());

        let tmp = path.with_extension("tmp");
        {
            let mut f = std::fs::File::create(&tmp)?;
            f.write_all(&buf)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)?;
        // The rename itself is directory metadata: fsync the directory
        // so the new snapshot is durable before the caller truncates
        // the WAL — otherwise power loss could keep the truncation but
        // drop the rename, losing every folded record.
        #[cfg(unix)]
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::File::open(parent)?.sync_all()?;
        }
        Ok(buf.len() as u64)
    }

    /// Load and validate a snapshot (magic, checksum, exact framing).
    /// Accepts the current `CMHSNAP2` format and legacy `CMHSNAP1`
    /// (no scheme field; decoded as `cmh` — see the module docs).
    pub fn load(path: &Path) -> crate::Result<SnapshotData> {
        let bytes = std::fs::read(path)?;
        if bytes.len() < 8 + 8 {
            return Err(bad("file too short"));
        }
        let (body, crc_bytes) = bytes.split_at(bytes.len() - 8);
        let mut crc = [0u8; 8];
        crc.copy_from_slice(crc_bytes);
        if fnv1a64(body) != u64::from_le_bytes(crc) {
            return Err(bad("checksum mismatch"));
        }
        let magic: &[u8] = &body[..8];
        let (scheme_field_len, version) = if magic == MAGIC_V2 {
            (4usize, 2u32)
        } else if magic == MAGIC_V1 {
            (0usize, 1u32)
        } else {
            return Err(bad("bad magic"));
        };
        let header = 8 + 4 + scheme_field_len + 8 + 8;
        if body.len() < header {
            return Err(bad("file too short"));
        }
        let k = u32::from_le_bytes(body[8..12].try_into().unwrap()) as usize;
        let scheme = if version == 2 {
            let code = u32::from_le_bytes(body[12..16].try_into().unwrap());
            SketchScheme::from_code(code)?
        } else {
            SketchScheme::Cmh
        };
        let off0 = 12 + scheme_field_len;
        let next_id = u64::from_le_bytes(body[off0..off0 + 8].try_into().unwrap());
        let count =
            u64::from_le_bytes(body[off0 + 8..off0 + 16].try_into().unwrap()) as usize;
        let item_bytes = count
            .checked_mul(8 + 4 * k)
            .ok_or_else(|| bad("count overflow"))?;
        if body.len() - header != item_bytes {
            return Err(bad(format!(
                "expected {item_bytes} item bytes, found {}",
                body.len() - header
            )));
        }
        let mut items = Vec::with_capacity(count);
        let mut off = header;
        for _ in 0..count {
            let id = u64::from_le_bytes(body[off..off + 8].try_into().unwrap());
            off += 8;
            let mut sketch = Vec::with_capacity(k);
            for _ in 0..k {
                sketch.push(u32::from_le_bytes(body[off..off + 4].try_into().unwrap()));
                off += 4;
            }
            items.push((id, sketch));
        }
        Ok(SnapshotData {
            k,
            scheme,
            next_id,
            items,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;

    fn sample_items() -> Vec<(u64, Vec<u32>)> {
        vec![
            (0, vec![5, 6, 7]),
            (2, vec![1, 2, 3]),
            (9, vec![u32::MAX, 0, 42]),
        ]
    }

    #[test]
    fn write_load_roundtrip() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        let bytes =
            Snapshot::write(&path, 3, SketchScheme::Cmh, 10, &sample_items()).unwrap();
        assert_eq!(bytes, std::fs::metadata(&path).unwrap().len());
        let data = Snapshot::load(&path).unwrap();
        assert_eq!(data.k, 3);
        assert_eq!(data.scheme, SketchScheme::Cmh);
        assert_eq!(data.next_id, 10);
        assert_eq!(data.items, sample_items());
    }

    #[test]
    fn every_scheme_roundtrips_through_the_header() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        for scheme in SketchScheme::ALL {
            Snapshot::write(&path, 3, scheme, 7, &sample_items()).unwrap();
            assert_eq!(Snapshot::load(&path).unwrap().scheme, scheme);
        }
    }

    #[test]
    fn empty_snapshot_roundtrips() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        Snapshot::write(&path, 64, SketchScheme::Coph, 0, &[]).unwrap();
        let data = Snapshot::load(&path).unwrap();
        assert!(data.items.is_empty());
        assert_eq!(data.k, 64);
        assert_eq!(data.scheme, SketchScheme::Coph);
    }

    #[test]
    fn legacy_v1_snapshot_loads_as_cmh() {
        // Hand-roll a CMHSNAP1 image (the pre-scheme format): the
        // migration contract is that it decodes with scheme = cmh.
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        let k = 3usize;
        let items = sample_items();
        let mut buf = Vec::new();
        buf.extend_from_slice(b"CMHSNAP1");
        buf.extend_from_slice(&(k as u32).to_le_bytes());
        buf.extend_from_slice(&10u64.to_le_bytes());
        buf.extend_from_slice(&(items.len() as u64).to_le_bytes());
        for (id, sketch) in &items {
            buf.extend_from_slice(&id.to_le_bytes());
            for v in sketch {
                buf.extend_from_slice(&v.to_le_bytes());
            }
        }
        let crc = crate::util::fnv::fnv1a64(&buf);
        buf.extend_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &buf).unwrap();

        let data = Snapshot::load(&path).unwrap();
        assert_eq!(data.scheme, SketchScheme::Cmh, "v1 predates schemes");
        assert_eq!(data.k, k);
        assert_eq!(data.next_id, 10);
        assert_eq!(data.items, items);
    }

    #[test]
    fn rewrite_is_atomic_replacement() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        Snapshot::write(&path, 3, SketchScheme::Cmh, 5, &sample_items()).unwrap();
        Snapshot::write(&path, 3, SketchScheme::Cmh, 6, &sample_items()[..1]).unwrap();
        let data = Snapshot::load(&path).unwrap();
        assert_eq!(data.next_id, 6);
        assert_eq!(data.items.len(), 1);
        assert!(!path.with_extension("tmp").exists(), "tmp cleaned up");
    }

    #[test]
    fn corruption_is_detected() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("snapshot.bin");
        Snapshot::write(&path, 3, SketchScheme::Cmh, 10, &sample_items()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[30] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        assert!(Snapshot::load(&path).is_err(), "checksum must catch flips");
        // truncation is also caught
        let good = {
            Snapshot::write(&path, 3, SketchScheme::Cmh, 10, &sample_items()).unwrap();
            std::fs::read(&path).unwrap()
        };
        std::fs::write(&path, &good[..good.len() - 3]).unwrap();
        assert!(Snapshot::load(&path).is_err());
        // wrong-length sketches are rejected at write time
        assert!(
            Snapshot::write(&path, 4, SketchScheme::Cmh, 0, &sample_items()).is_err()
        );
    }
}
