//! The sketch-store subsystem: a sharded, optionally durable home for
//! sketches and their LSH postings.
//!
//! ```text
//!            PersistentIndex
//!            ┌──────────────────────────────────────────┐
//! insert ───▶│ WAL append ──▶ ShardedIndex (id-hash     │
//! delete ───▶│ (serialized)     routed, RwLock/shard)   │
//! query  ───▶│ ShardedIndex fan-out (scoped threads) ───▶ merged top-k
//! compact ──▶│ Snapshot::write + WAL reset              │
//!            └──────────────────────────────────────────┘
//! recovery:  Snapshot::load ─▶ WAL replay (upsert) ─▶ serving state
//! ```
//!
//! [`ShardedIndex`] is the pure in-memory layer (usable on its own —
//! the `index_scale` bench drives it directly); [`PersistentIndex`]
//! adds the write-ahead log and snapshot compaction when a persist
//! directory is configured, and degrades to a thin pass-through when
//! it is not.

mod sharded;
mod snapshot;
mod wal;

pub(crate) use sharded::mix64;
pub use sharded::{resolve_shards, ShardOps, ShardedIndex};
pub use snapshot::{Snapshot, SnapshotData};
pub use wal::{Wal, WalRecord};

use crate::index::{IndexConfig, Neighbor};
use crate::metrics::{LatencyHistogram, LatencySnapshot};
use crate::obs::{stage, Stage};
use crate::sketch::SketchScheme;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Distinguishes concurrent [`PersistentIndex::replicate_apply`]
/// validation scratch files within one process (tests run in
/// parallel; in-memory stores validate under the shared temp dir).
static APPLY_SEQ: AtomicU64 = AtomicU64::new(0);

/// Snapshot file name inside the persist directory.
pub const SNAPSHOT_FILE: &str = "snapshot.bin";
/// WAL file name inside the persist directory.
pub const WAL_FILE: &str = "wal.log";

/// Occupancy and durability snapshot of the store subsystem
/// (the store half of the `stats` wire response).
#[derive(Clone, Debug, PartialEq)]
pub struct StoreStats {
    /// Total sketches resident.
    pub stored: usize,
    /// Items per shard.
    pub shards: Vec<usize>,
    /// Bytes on disk (snapshot + WAL); 0 without persistence.
    pub persisted_bytes: u64,
    /// Bits stored per hash (32 = full width, < 32 = packed plane).
    pub bits: u8,
    /// Resident bytes per stored sketch (truthful across storage
    /// modes: K·4 full-width, K·bits/8 rounded up to words packed).
    pub sketch_bytes: u64,
    /// WAL bytes appended since service start (monotone; unlike
    /// `persisted_bytes` it never shrinks at compaction).
    pub wal_appended_bytes: u64,
    /// Durability fsync latency at compaction (snapshot write + WAL
    /// truncation, the store's only fsync site).
    pub fsync: LatencySnapshot,
    /// Insert/delete/probe counts, by shard.
    pub shard_ops: Vec<ShardOps>,
    /// Occupied band-signature buckets across all shards.
    pub band_buckets: usize,
    /// Largest single band posting list (collision hot spot).
    pub band_max_bucket: usize,
    /// LSH candidates scored across all queries since start.
    pub candidates: u64,
}

struct PersistState {
    dir: PathBuf,
    wal: Wal,
    snapshot_bytes: u64,
}

/// A [`ShardedIndex`] with optional crash recovery: every mutation is
/// WAL-logged before the call returns, and [`PersistentIndex::compact`]
/// folds the log into a fresh snapshot.
///
/// Mutations are serialized through the WAL lock (appends are
/// inherently sequential); queries go straight to the sharded index
/// and stay parallel.  Without a persist directory there is no WAL
/// lock and mutations contend only on their owning shard.
pub struct PersistentIndex {
    index: ShardedIndex,
    /// The hashing scheme the stored sketches were produced by —
    /// stamped into every snapshot and matched on open, since sketches
    /// from different schemes are incomparable bytes.
    scheme: SketchScheme,
    persist: Option<Mutex<PersistState>>,
    /// Compaction durability latency (the only fsync site).
    fsync_us: LatencyHistogram,
    /// WAL bytes appended since open (monotone across compactions).
    wal_appended: AtomicU64,
}

// The persist-lock guards WAL order == apply order; a poisoned lock
// means a writer panicked mid-mutation and the only safe move is to
// crash and recover from WAL + snapshot.  Every `.lock().unwrap()`
// (and the length-checked `pop().expect`) below is that idiom — see
// clippy.toml and docs/LINTS.md.
#[allow(clippy::disallowed_methods)]
impl PersistentIndex {
    /// Open a store for sketches of length `k` produced by `scheme`.
    /// With `dir` set, an existing snapshot is loaded (refusing a
    /// snapshot stamped with a different K or scheme), the WAL's valid
    /// prefix is replayed on top (inserts upsert, deletes tolerate
    /// missing ids — so any snapshot/WAL interleaving recovers
    /// cleanly), and the WAL is kept open for append.  A directory
    /// with no snapshot is stamped with an empty scheme-carrying one
    /// before the WAL accepts its first record, so every durable
    /// directory knows its scheme from birth — which makes a
    /// record-bearing WAL without a snapshot provably a legacy
    /// pre-scheme store (necessarily `cmh` at full width; any other
    /// configured scheme or width is refused).  With `dir = None` the
    /// store is purely in-memory.
    ///
    /// Equivalent to [`PersistentIndex::open_with_bits`] at
    /// `bits = 32` (full-width rows).
    pub fn open(
        k: usize,
        scheme: SketchScheme,
        cfg: IndexConfig,
        num_shards: usize,
        dir: Option<&Path>,
    ) -> crate::Result<Self> {
        Self::open_with_bits(k, scheme, 32, cfg, num_shards, dir)
    }

    /// [`PersistentIndex::open`] with an explicit sketch width:
    /// `bits = 32` keeps full `u32` rows and the exact pre-b-bit
    /// on-disk formats; `bits < 32` stores, snapshots, and WAL-logs
    /// bit-packed rows.  The width is stamped into the snapshot
    /// alongside K and the scheme, and a mismatched width refuses to
    /// open with an error naming both — packed lanes from different
    /// widths are incomparable bytes, exactly like sketches from
    /// different schemes.
    pub fn open_with_bits(
        k: usize,
        scheme: SketchScheme,
        bits: u8,
        cfg: IndexConfig,
        num_shards: usize,
        dir: Option<&Path>,
    ) -> crate::Result<Self> {
        let index = ShardedIndex::with_bits(k, cfg, bits, num_shards)?;
        let Some(dir) = dir else {
            return Ok(PersistentIndex {
                index,
                scheme,
                persist: None,
                fsync_us: LatencyHistogram::default(),
                wal_appended: AtomicU64::new(0),
            });
        };
        std::fs::create_dir_all(dir)?;
        let snap_path = dir.join(SNAPSHOT_FILE);
        let wal_has_records = std::fs::metadata(dir.join(WAL_FILE))
            .map(|m| m.len() > 0)
            .unwrap_or(false);
        // `None` = the directory still needs its (K, scheme) stamp —
        // written only *after* WAL replay succeeds, so an open that
        // fails (e.g. replaying a legacy WAL under the wrong K) never
        // wedges the directory behind a half-true stamp.
        let mut snapshot_bytes: Option<u64> = None;
        if snap_path.exists() {
            let data = Snapshot::load(&snap_path)?;
            // A stamp with no data behind it (no items, no id ever
            // allocated, no WAL records) pins nothing: a mis-started
            // server may leave one, so allow re-stamping it under the
            // new configuration instead of demanding hand-deletion.
            let empty_stamp =
                data.items.is_empty() && data.next_id == 0 && !wal_has_records;
            if data.k != k && !empty_stamp {
                return Err(crate::Error::Invalid(format!(
                    "snapshot in {} has K={}, configured K={k}",
                    dir.display(),
                    data.k
                )));
            }
            if data.scheme != scheme && !empty_stamp {
                return Err(crate::Error::Invalid(format!(
                    "snapshot in {} was written under scheme '{}' but the \
                     service is configured for '{scheme}'; sketches from \
                     different schemes are incomparable — serve this \
                     directory with --scheme {}, or re-ingest the corpus \
                     into a fresh directory under the new scheme",
                    dir.display(),
                    data.scheme,
                    data.scheme
                )));
            }
            if data.bits != bits && !empty_stamp {
                return Err(crate::Error::Invalid(format!(
                    "snapshot in {} was written at bits={} but the service \
                     is configured for bits={bits}; packed lanes from \
                     different widths are incomparable — serve this \
                     directory with --bits {}, or re-ingest the corpus \
                     into a fresh directory under the new width",
                    dir.display(),
                    data.bits,
                    data.bits
                )));
            }
            if data.k == k && data.scheme == scheme && data.bits == bits {
                // Bulk load: band postings rebuild shard-parallel above
                // the fan-out threshold, with state identical to a
                // serial insert_with_id replay.
                index.load_items(&data.items)?;
                index.reserve_ids(data.next_id);
                snapshot_bytes = Some(std::fs::metadata(&snap_path)?.len());
            }
            // else: a mismatched but empty stamp — fall through and
            // re-stamp under the configured (K, scheme, bits) after
            // replay.
        } else if wal_has_records && (scheme != SketchScheme::Cmh || bits != 32) {
            // No snapshot but a record-bearing WAL.  This build stamps
            // a directory at its first successful open, before any
            // record can be appended, so this state can only be a
            // legacy pre-scheme store — necessarily written by the
            // cmh-only, full-width era.  Refusing any other scheme or
            // width here closes the gap where a WAL-only store would
            // silently replay incomparable sketches under a
            // freshly-configured scheme/width and then be re-stamped
            // wrongly later.
            return Err(crate::Error::Invalid(format!(
                "{} holds WAL records but no snapshot: a legacy \
                 pre-stamp store, necessarily written under 'cmh' at \
                 full width, which cannot be served as '{scheme}' at \
                 bits={bits} — open it with --scheme cmh --bits 32, or \
                 re-ingest the corpus into a fresh directory under the \
                 new configuration",
                dir.display()
            )));
        }
        let (wal, records) = Wal::open(&dir.join(WAL_FILE))?;
        for rec in records {
            match rec {
                WalRecord::Insert { id, sketch } => {
                    let _ = index.delete(id);
                    index.insert_with_id(id, &sketch)?;
                }
                WalRecord::InsertBatch { items } => {
                    for (id, sketch) in items {
                        let _ = index.delete(id);
                        index.insert_with_id(id, &sketch)?;
                    }
                }
                WalRecord::InsertPacked {
                    bits: rec_bits,
                    items,
                } => {
                    // A packed record can only postdate this build's
                    // width stamp; its width disagreeing with the
                    // configuration means the directory was tampered
                    // with or mixed — refuse rather than remask lanes.
                    if rec_bits != bits {
                        return Err(crate::Error::Invalid(format!(
                            "WAL in {} holds packed rows at bits={rec_bits} \
                             but the service is configured for bits={bits}",
                            dir.display()
                        )));
                    }
                    for (id, sketch) in items {
                        let _ = index.delete(id);
                        index.insert_with_id(id, &sketch)?;
                    }
                }
                WalRecord::Delete { id } => {
                    let _ = index.delete(id);
                }
            }
        }
        // Replay succeeded: stamp the directory if it still needs one
        // (fresh dir, legacy cmh store, or an abandoned empty stamp
        // being re-stamped).  From here on every record the WAL ever
        // holds postdates a scheme- and width-carrying snapshot.
        let snapshot_bytes = match snapshot_bytes {
            Some(bytes) => bytes,
            None => Snapshot::write(&snap_path, k, scheme, bits, 0, &[])?,
        };
        Ok(PersistentIndex {
            index,
            scheme,
            persist: Some(Mutex::new(PersistState {
                dir: dir.to_path_buf(),
                wal,
                snapshot_bytes,
            })),
            fsync_us: LatencyHistogram::default(),
            wal_appended: AtomicU64::new(0),
        })
    }

    /// Append `rec` under an active [`Stage::WalAppend`] span and
    /// credit the appended bytes to the monotone WAL byte counter.
    fn wal_append(&self, st: &mut PersistState, rec: &WalRecord) -> crate::Result<()> {
        let _span = stage(Stage::WalAppend);
        let before = st.wal.bytes();
        st.wal.append(rec)?;
        self.wal_appended
            .fetch_add(st.wal.bytes() - before, Ordering::Relaxed);
        Ok(())
    }

    /// The underlying sharded index.
    pub fn sharded(&self) -> &ShardedIndex {
        &self.index
    }

    /// The hashing scheme this store's sketches were produced by.
    pub fn scheme(&self) -> SketchScheme {
        self.scheme
    }

    /// True iff a persist directory is configured.
    pub fn is_durable(&self) -> bool {
        self.persist.is_some()
    }

    /// The WAL record for freshly inserted `(id, sketch)` rows: the
    /// full-width record family at `bits = 32` (byte-identical to the
    /// pre-b-bit log), one packed record otherwise.  Packed rows need
    /// no pre-masking here: the codec's `pack_row` masks every lane on
    /// encode, so the logged bytes are exactly what the store serves
    /// and a replay reconstructs resident state bit-for-bit.
    fn insert_record(&self, mut items: Vec<(u64, Vec<u32>)>) -> WalRecord {
        let bits = self.index.bits();
        if bits == 32 {
            if items.len() == 1 {
                let (id, sketch) = items.pop().expect("one item");
                WalRecord::Insert { id, sketch }
            } else {
                WalRecord::InsertBatch { items }
            }
        } else {
            WalRecord::InsertPacked { bits, items }
        }
    }

    /// Insert a sketch under a fresh id, WAL-logging it first-class.
    /// If the log append fails (disk full, I/O error) the in-memory
    /// insert is rolled back, so memory and log never diverge; the
    /// burned id is simply never reused.
    pub fn insert(&self, sketch: Vec<u32>) -> crate::Result<u64> {
        match &self.persist {
            None => self.index.insert(&sketch),
            Some(m) => {
                let mut st = m.lock().unwrap();
                let id = self.index.insert(&sketch)?;
                let rec = self.insert_record(vec![(id, sketch)]);
                if let Err(e) = self.wal_append(&mut st, &rec) {
                    let _ = self.index.delete(id);
                    return Err(e);
                }
                Ok(id)
            }
        }
    }

    /// Insert a whole batch of sketches under fresh consecutive ids,
    /// WAL-logged as **one** [`WalRecord::InsertBatch`] record under
    /// one checksum — so the batch is all-or-nothing both on an
    /// in-process append failure (every in-memory insert is rolled
    /// back; the burned ids are simply never reused) *and* across a
    /// crash mid-write (a torn record fails its CRC and recovery
    /// truncates the whole batch away).  Each shard lock is taken
    /// once per batch, not once per row.
    pub fn insert_many(&self, sketches: &[Vec<u32>]) -> crate::Result<Vec<u64>> {
        match &self.persist {
            None => self.index.insert_many(sketches),
            Some(m) => {
                let mut st = m.lock().unwrap();
                let ids = self.index.insert_many(sketches)?;
                let rec = self.insert_record(
                    ids.iter()
                        .zip(sketches)
                        .map(|(&id, sketch)| (id, sketch.clone()))
                        .collect(),
                );
                if let Err(e) = self.wal_append(&mut st, &rec) {
                    for &id in &ids {
                        let _ = self.index.delete(id);
                    }
                    return Err(e);
                }
                Ok(ids)
            }
        }
    }

    /// Insert a batch of *already-packed* rows (words as produced by
    /// [`crate::sketch::pack_row`] at this store's K and width) under
    /// fresh consecutive ids — the binary wire's ingest path.  The
    /// in-memory side is a pure memcpy per row; with a persist
    /// directory the rows are widened back to lanes **only for the WAL
    /// record**, because [`WalRecord::InsertPacked`] stores lane items
    /// so replay can reuse the ordinary upsert path.  Same
    /// all-or-nothing and rollback contract as
    /// [`PersistentIndex::insert_many`].
    pub fn insert_packed_many(&self, rows: &[Vec<u64>]) -> crate::Result<Vec<u64>> {
        match &self.persist {
            None => self.index.insert_packed_many(rows),
            Some(m) => {
                let mut st = m.lock().unwrap();
                let ids = self.index.insert_packed_many(rows)?;
                let k = self.index.num_hashes();
                let bits = self.index.bits();
                let rec = self.insert_record(
                    ids.iter()
                        .zip(rows)
                        .map(|(&id, words)| {
                            (id, crate::sketch::unpack_row(words, k, bits))
                        })
                        .collect(),
                );
                if let Err(e) = self.wal_append(&mut st, &rec) {
                    for &id in &ids {
                        let _ = self.index.delete(id);
                    }
                    return Err(e);
                }
                Ok(ids)
            }
        }
    }

    /// Delete an id (error on unknown ids), WAL-logging the removal.
    /// If the log append fails the in-memory delete is rolled back
    /// (re-inserted under the same id), so a delete the client saw
    /// fail can never silently take effect after a restart — and a
    /// logged delete never resurrects.
    pub fn delete(&self, id: u64) -> crate::Result<()> {
        match &self.persist {
            None => {
                self.index.delete(id)?;
                Ok(())
            }
            Some(m) => {
                let mut st = m.lock().unwrap();
                let removed = self.index.delete(id)?;
                if let Err(e) = self.wal_append(&mut st, &WalRecord::Delete { id }) {
                    let _ = self.index.insert_with_id(id, &removed);
                    return Err(e);
                }
                Ok(())
            }
        }
    }

    /// Fold the WAL into a fresh snapshot (fsynced, atomically
    /// replaced) and truncate the log.  Returns total persisted bytes.
    /// Errors without a persist directory.
    pub fn compact(&self) -> crate::Result<u64> {
        let Some(m) = &self.persist else {
            return Err(crate::Error::Invalid(
                "no persist_dir configured; nothing to compact".into(),
            ));
        };
        let mut st = m.lock().unwrap();
        let snap_path = st.dir.join(SNAPSHOT_FILE);
        let durable_start = Instant::now();
        // Packed stores snapshot their rows as the words they already
        // hold — widening every lane to u32 first would transiently
        // cost 32/b× the packed footprint, exactly when the corpus is
        // big enough for that to hurt.
        let bytes = match self.index.packed_items() {
            Some(items) => Snapshot::write_packed(
                &snap_path,
                self.index.num_hashes(),
                self.scheme,
                self.index.bits(),
                self.index.next_id(),
                &items,
            )?,
            None => Snapshot::write(
                &snap_path,
                self.index.num_hashes(),
                self.scheme,
                self.index.bits(),
                self.index.next_id(),
                &self.index.items(),
            )?,
        };
        // The snapshot is durable (fsynced file + directory entry);
        // make the truncation durable too so a reboot never replays a
        // stale pre-compaction log on top of the new snapshot (replay
        // is idempotent, but a long stale log costs startup time).
        st.wal.reset()?;
        st.wal.sync()?;
        // One observation per compaction covering the whole durable
        // sequence (snapshot fsyncs + WAL truncation fsync) — the
        // latency a caller actually waits on for durability.
        self.fsync_us
            .record(durable_start.elapsed().as_micros() as u64);
        st.snapshot_bytes = bytes;
        Ok(bytes)
    }

    /// Export this store's durable image for a joining replica: the
    /// raw snapshot bytes plus the raw WAL-tail bytes, read under the
    /// persist lock so the pair is one consistent cut — no mutation
    /// can land between the two reads.  Replication ships on-disk
    /// bytes verbatim, so this errors without a persist directory: an
    /// in-memory node has no durable image to offer.
    pub fn replicate_export(&self) -> crate::Result<(Vec<u8>, Vec<u8>)> {
        let Some(m) = &self.persist else {
            return Err(crate::Error::Invalid(
                "no persist_dir configured; nothing to replicate from".into(),
            ));
        };
        let st = m.lock().unwrap();
        let snapshot = std::fs::read(st.dir.join(SNAPSHOT_FILE))?;
        let wal = std::fs::read(st.dir.join(WAL_FILE))?;
        Ok((snapshot, wal))
    }

    /// Join from a peer's [`PersistentIndex::replicate_export`] image:
    /// validate both streams fully, then install them.  The receiving
    /// store must be empty (a joining node is fresh by contract — this
    /// is a bootstrap, not a merge), and **nothing is mutated until
    /// both streams have been validated end to end**: the snapshot
    /// must pass [`Snapshot::load`] (magic, checksum, exact framing)
    /// and carry this store's K/scheme/bits stamp, and the WAL bytes
    /// must decode as a *whole* image ([`Wal::decode_all`] — a torn
    /// tail that local recovery would forgive is a transport fault
    /// here) with every record matching this store's shape.
    ///
    /// On a durable store the peer's snapshot bytes are installed
    /// verbatim (temp file + fsync + rename, like compaction) and the
    /// WAL records are re-encoded through the ordinary append path —
    /// the codec is deterministic, so the resulting on-disk pair is
    /// byte-identical to the peer's export.  An in-memory store
    /// installs the decoded state only (validation still runs the
    /// snapshot bytes through a scratch file so there is exactly one
    /// snapshot decoder).  Returns the number of resident items.
    pub fn replicate_apply(&self, snapshot: &[u8], wal: &[u8]) -> crate::Result<u64> {
        if !self.index.is_empty() || self.index.next_id() != 0 {
            return Err(crate::Error::Invalid(
                "replicate_apply needs a fresh store: this node already \
                 holds data — joining from a peer is a bootstrap, not a \
                 merge"
                    .into(),
            ));
        }
        let k = self.index.num_hashes();
        let bits = self.index.bits();
        // Validate the snapshot stream through the one snapshot
        // decoder (a scratch file feeds `Snapshot::load`); refuse a
        // peer whose stamp disagrees with this store's configuration.
        let scratch_dir = match &self.persist {
            Some(m) => m.lock().unwrap().dir.clone(),
            None => std::env::temp_dir(),
        };
        let scratch = scratch_dir.join(format!(
            "replicate-{}-{}.tmp",
            std::process::id(),
            APPLY_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&scratch, snapshot)?;
        let loaded = Snapshot::load(&scratch);
        let _ = std::fs::remove_file(&scratch);
        let data = loaded.map_err(|e| {
            crate::Error::Invalid(format!("replicate: bad snapshot stream: {e}"))
        })?;
        if data.k != k || data.scheme != self.scheme || data.bits != bits {
            return Err(crate::Error::Invalid(format!(
                "replicate: peer image is (K={}, scheme={}, bits={}) but \
                 this node is configured for (K={k}, scheme={}, bits={bits})",
                data.k, data.scheme, data.bits, self.scheme
            )));
        }
        // Validate the WAL stream: whole-image decode, then shape.
        let records = Wal::decode_all(wal).ok_or_else(|| {
            crate::Error::Invalid(
                "replicate: bad WAL stream: torn, corrupt, or trailing \
                 garbage"
                    .into(),
            )
        })?;
        for rec in &records {
            let (rec_bits, lens): (u8, Vec<usize>) = match rec {
                WalRecord::Insert { sketch, .. } => (32, vec![sketch.len()]),
                WalRecord::InsertBatch { items } => {
                    (32, items.iter().map(|(_, s)| s.len()).collect())
                }
                WalRecord::InsertPacked { bits: b, items } => {
                    (*b, items.iter().map(|(_, s)| s.len()).collect())
                }
                WalRecord::Delete { .. } => continue,
            };
            if rec_bits != 32 && rec_bits != bits {
                return Err(crate::Error::Invalid(format!(
                    "replicate: WAL stream holds packed rows at \
                     bits={rec_bits} but this node is configured for \
                     bits={bits}"
                )));
            }
            if let Some(bad) = lens.iter().find(|&&l| l != k) {
                return Err(crate::Error::Invalid(format!(
                    "replicate: WAL stream holds a sketch of length {bad}, \
                     expected K={k}"
                )));
            }
        }
        // Both streams verified — install.  Memory first (replaying
        // exactly like recovery: the snapshot bulk-loads shard-parallel
        // on large images, WAL inserts upsert, deletes tolerate missing
        // ids), then disk under the persist lock.
        self.index.load_items(&data.items)?;
        self.index.reserve_ids(data.next_id);
        for rec in &records {
            match rec {
                WalRecord::Insert { id, sketch } => {
                    let _ = self.index.delete(*id);
                    self.index.insert_with_id(*id, sketch)?;
                }
                WalRecord::InsertBatch { items }
                | WalRecord::InsertPacked { items, .. } => {
                    for (id, sketch) in items {
                        let _ = self.index.delete(*id);
                        self.index.insert_with_id(*id, sketch)?;
                    }
                }
                WalRecord::Delete { id } => {
                    let _ = self.index.delete(*id);
                }
            }
        }
        if let Some(m) = &self.persist {
            let mut st = m.lock().unwrap();
            let durable_start = Instant::now();
            // The peer's snapshot bytes land verbatim through the same
            // atomic temp+fsync+rename dance as compaction.
            let snap_path = st.dir.join(SNAPSHOT_FILE);
            let tmp = snap_path.with_extension("tmp");
            {
                let mut f = std::fs::File::create(&tmp)?;
                f.write_all(snapshot)?;
                f.sync_all()?;
            }
            std::fs::rename(&tmp, &snap_path)?;
            #[cfg(unix)]
            if let Some(parent) =
                snap_path.parent().filter(|p| !p.as_os_str().is_empty())
            {
                std::fs::File::open(parent)?.sync_all()?;
            }
            st.snapshot_bytes = snapshot.len() as u64;
            // Re-encoding the validated records through the ordinary
            // append path reproduces the peer's WAL byte-for-byte —
            // the codec is deterministic.
            st.wal.reset()?;
            for rec in &records {
                self.wal_append(&mut st, rec)?;
            }
            st.wal.sync()?;
            self.fsync_us
                .record(durable_start.elapsed().as_micros() as u64);
        }
        Ok(self.index.len() as u64)
    }

    /// Top-k neighbors of a query sketch.
    pub fn query(&self, sketch: &[u32], topk: usize) -> crate::Result<Vec<Neighbor>> {
        self.index.query(sketch, topk)
    }

    /// Top-k neighbors for a batch of query sketches (one shard lock
    /// acquisition per shard per batch).
    pub fn query_many(
        &self,
        sketches: &[Vec<u32>],
        topk: usize,
    ) -> crate::Result<Vec<Vec<Neighbor>>> {
        self.index.query_many(sketches, topk)
    }

    /// All neighbors with estimate ≥ `threshold`.
    pub fn query_above(&self, sketch: &[u32], threshold: f64) -> crate::Result<Vec<Neighbor>> {
        self.index.query_above(sketch, threshold)
    }

    /// Estimate J between two stored ids.
    pub fn estimate(&self, a: u64, b: u64) -> crate::Result<f64> {
        self.index.estimate(a, b)
    }

    /// Stored sketch for an id.
    pub fn sketch(&self, id: u64) -> Option<Vec<u32>> {
        self.index.sketch(id)
    }

    /// Total sketches resident.
    pub fn len(&self) -> usize {
        self.index.len()
    }

    /// True iff empty.
    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Occupancy + durability snapshot.
    pub fn stats(&self) -> StoreStats {
        let persisted_bytes = match &self.persist {
            None => 0,
            Some(m) => {
                let st = m.lock().unwrap();
                st.snapshot_bytes + st.wal.bytes()
            }
        };
        let (band_buckets, band_max_bucket) = self.index.band_stats();
        StoreStats {
            stored: self.index.len(),
            shards: self.index.shard_sizes(),
            persisted_bytes,
            bits: self.index.bits(),
            sketch_bytes: self.index.sketch_bytes_per_item() as u64,
            wal_appended_bytes: self.wal_appended.load(Ordering::Relaxed),
            fsync: (&self.fsync_us).into(),
            shard_ops: self.index.shard_ops(),
            band_buckets,
            band_max_bucket,
            candidates: self.index.candidates_collected(),
        }
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;

    fn cfg() -> IndexConfig {
        IndexConfig {
            bands: 4,
            rows_per_band: 2,
        }
    }

    fn sk(seed: u32) -> Vec<u32> {
        (0..8).map(|i| seed.wrapping_mul(31).wrapping_add(i)).collect()
    }

    #[test]
    fn in_memory_mode_has_no_disk_footprint() {
        let store = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, None).unwrap();
        assert!(!store.is_durable());
        let id = store.insert(sk(1)).unwrap();
        store.delete(id).unwrap();
        assert!(store.compact().is_err());
        assert_eq!(store.stats().persisted_bytes, 0);
    }

    #[test]
    fn wal_only_recovery() {
        let dir = TempDir::new().unwrap();
        let (a, b);
        {
            let store = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, Some(dir.path())).unwrap();
            a = store.insert(sk(1)).unwrap();
            b = store.insert(sk(2)).unwrap();
            store.delete(a).unwrap();
            // dropped without compacting: recovery is pure WAL replay
        }
        let store = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, Some(dir.path())).unwrap();
        assert_eq!(store.len(), 1);
        assert!(store.sketch(a).is_none(), "deleted id must stay deleted");
        assert_eq!(store.sketch(b), Some(sk(2)));
        // fresh ids continue past everything ever allocated
        assert_eq!(store.insert(sk(3)).unwrap(), 2);
    }

    #[test]
    fn snapshot_plus_wal_recovery_and_compaction() {
        let dir = TempDir::new().unwrap();
        {
            let store = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 4, Some(dir.path())).unwrap();
            for s in 0..6u32 {
                store.insert(sk(s)).unwrap();
            }
            store.delete(0).unwrap();
            let bytes = store.compact().unwrap();
            assert!(bytes > 0);
            // post-snapshot tail lives only in the WAL
            store.insert(sk(100)).unwrap(); // id 6
            store.delete(3).unwrap();
        }
        let store = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 4, Some(dir.path())).unwrap();
        assert_eq!(store.len(), 5);
        for gone in [0u64, 3] {
            assert!(store.sketch(gone).is_none());
        }
        assert_eq!(store.sketch(6), Some(sk(100)));
        let stats = store.stats();
        assert_eq!(stats.stored, 5);
        assert_eq!(stats.shards.len(), 4);
        assert!(stats.persisted_bytes > 0);
        // compaction shrinks the footprint to snapshot-only
        let compacted = store.compact().unwrap();
        assert_eq!(store.stats().persisted_bytes, compacted);
    }

    #[test]
    fn stats_expose_wal_fsync_and_shard_op_telemetry() {
        let dir = TempDir::new().unwrap();
        let store =
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, Some(dir.path()))
                .unwrap();
        let before = store.stats();
        assert_eq!(before.wal_appended_bytes, 0);
        assert_eq!(before.fsync.count, 0);
        assert_eq!(before.candidates, 0);
        let a = store.insert(sk(1)).unwrap();
        store.insert_many(&[sk(2), sk(3)]).unwrap();
        store.delete(a).unwrap();
        store.query(&sk(2), 2).unwrap();
        store.compact().unwrap();
        let after = store.stats();
        // the monotone WAL byte counter survives the compaction that
        // resets the live log to zero bytes
        assert!(after.wal_appended_bytes > 0);
        assert_eq!(after.fsync.count, 1, "one compaction, one observation");
        assert_eq!(after.shard_ops.len(), 2);
        assert_eq!(after.shard_ops.iter().map(|o| o.inserts).sum::<u64>(), 3);
        assert_eq!(after.shard_ops.iter().map(|o| o.deletes).sum::<u64>(), 1);
        assert!(after.shard_ops.iter().all(|o| o.queries == 1));
        assert!(after.band_buckets > 0);
        assert!(after.band_max_bucket >= 1);
        assert!(after.candidates >= 1, "the self-probe scored itself");
        // in-memory stores report zeros for the durability telemetry
        let mem = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, None).unwrap();
        mem.insert(sk(1)).unwrap();
        let s = mem.stats();
        assert_eq!(s.wal_appended_bytes, 0);
        assert_eq!(s.fsync.count, 0);
    }

    #[test]
    fn insert_many_is_durable_and_recovers() {
        let dir = TempDir::new().unwrap();
        let ids;
        {
            let store = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, Some(dir.path())).unwrap();
            ids = store
                .insert_many(&[sk(1), sk(2), sk(3)])
                .unwrap();
            assert_eq!(ids, vec![0, 1, 2]);
            store.delete(ids[1]).unwrap();
            // dropped without compacting: recovery replays the batch
        }
        let store = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, Some(dir.path())).unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(store.sketch(ids[0]), Some(sk(1)));
        assert!(store.sketch(ids[1]).is_none());
        assert_eq!(store.sketch(ids[2]), Some(sk(3)));
        // batch queries agree with singleton queries after recovery
        let probes = vec![sk(1), sk(3)];
        let batched = store.query_many(&probes, 2).unwrap();
        assert_eq!(batched[0], store.query(&sk(1), 2).unwrap());
        assert_eq!(batched[1], store.query(&sk(3), 2).unwrap());
    }

    #[test]
    fn insert_packed_many_is_durable_and_recovers() {
        use crate::sketch::{pack_row, packed_words};
        // Pre-packed binary ingest must survive a crash exactly like
        // lane ingest: the WAL widens rows for the log, replay rebuilds
        // the same masked state, at packed and full widths alike.
        for bits in [8u8, 32] {
            let dir = TempDir::new().unwrap();
            let pack = |s: &[u32]| {
                let mut row = vec![0u64; packed_words(8, bits)];
                pack_row(s, bits, &mut row);
                row
            };
            let masked = |s: &[u32]| {
                s.iter()
                    .map(|&v| (u64::from(v) & ((1u64 << bits) - 1)) as u32)
                    .collect::<Vec<u32>>()
            };
            let ids;
            {
                let store = PersistentIndex::open_with_bits(
                    8,
                    SketchScheme::Cmh,
                    bits,
                    cfg(),
                    2,
                    Some(dir.path()),
                )
                .unwrap();
                ids = store
                    .insert_packed_many(&[pack(&sk(1)), pack(&sk(2))])
                    .unwrap();
                assert_eq!(ids, vec![0, 1], "bits={bits}");
                // dropped without compacting: recovery is pure WAL replay
            }
            let store = PersistentIndex::open_with_bits(
                8,
                SketchScheme::Cmh,
                bits,
                cfg(),
                2,
                Some(dir.path()),
            )
            .unwrap();
            assert_eq!(store.len(), 2, "bits={bits}");
            assert_eq!(store.sketch(ids[0]), Some(masked(&sk(1))), "bits={bits}");
            assert_eq!(store.sketch(ids[1]), Some(masked(&sk(2))), "bits={bits}");
            // the recovered rows score like lane-inserted ones
            assert_eq!(store.estimate(ids[0], ids[0]).unwrap(), 1.0);
            // width validation happens before any mutation
            assert!(store.insert_packed_many(&[vec![0u64; 99]]).is_err());
            assert_eq!(store.len(), 2, "bits={bits}: all-or-nothing");
        }
    }

    #[test]
    fn packed_store_recovers_from_wal_and_snapshot() {
        // The packed plane's crash-recovery contract: WAL-tail replay,
        // compaction, and reopen all reconstruct the same masked rows.
        let dir = TempDir::new().unwrap();
        let open8 = |d: &std::path::Path| {
            PersistentIndex::open_with_bits(8, SketchScheme::Cmh, 8, cfg(), 2, Some(d))
        };
        let masked = |s: &[u32]| s.iter().map(|&v| v & 0xff).collect::<Vec<u32>>();
        let (a, b, c);
        {
            let store = open8(dir.path()).unwrap();
            assert_eq!(store.stats().bits, 8);
            assert_eq!(store.stats().sketch_bytes, 8, "8 lanes × 8 bits = 1 word");
            a = store.insert(sk(1)).unwrap();
            let ids = store.insert_many(&[sk(2), sk(3)]).unwrap();
            b = ids[0];
            c = ids[1];
            store.delete(a).unwrap();
            // dropped without compacting: recovery is pure WAL replay
        }
        {
            let store = open8(dir.path()).unwrap();
            assert_eq!(store.len(), 2);
            assert!(store.sketch(a).is_none());
            assert_eq!(store.sketch(b), Some(masked(&sk(2))));
            assert_eq!(store.sketch(c), Some(masked(&sk(3))));
            assert_eq!(store.estimate(b, b).unwrap(), 1.0);
            // compact folds the packed rows into a CMHSNAP3 image
            assert!(store.compact().unwrap() > 0);
            store.insert(sk(4)).unwrap(); // WAL tail on top of the snapshot
        }
        let store = open8(dir.path()).unwrap();
        assert_eq!(store.len(), 3);
        assert_eq!(store.sketch(3), Some(masked(&sk(4))));
        // a self-probe through the recovered packed index is exact
        let hits = store.query(&sk(2), 1).unwrap();
        assert_eq!(hits[0].id, b);
        assert_eq!(hits[0].score, 1.0);
    }

    #[test]
    fn replicate_roundtrip_is_byte_identical() {
        let src = TempDir::new().unwrap();
        let dst = TempDir::new().unwrap();
        let a =
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, Some(src.path()))
                .unwrap();
        for s in 0..4u32 {
            a.insert(sk(s)).unwrap();
        }
        a.delete(1).unwrap();
        a.compact().unwrap();
        a.insert_many(&[sk(10), sk(11)]).unwrap(); // WAL tail
        a.delete(2).unwrap();
        let (snap, wal) = a.replicate_export().unwrap();
        assert!(!wal.is_empty(), "tail records live in the WAL");
        // a fresh durable node (different shard count — items are
        // id-sorted, so layout doesn't matter) joins byte-identical
        let b =
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 4, Some(dst.path()))
                .unwrap();
        let n = b.replicate_apply(&snap, &wal).unwrap();
        assert_eq!(n as usize, a.len());
        assert_eq!(b.sharded().items(), a.sharded().items());
        assert_eq!(std::fs::read(dst.path().join(SNAPSHOT_FILE)).unwrap(), snap);
        assert_eq!(std::fs::read(dst.path().join(WAL_FILE)).unwrap(), wal);
        // fresh ids continue past everything the peer ever allocated
        assert_eq!(b.insert(sk(99)).unwrap(), a.insert(sk(99)).unwrap());
        // ...and the joined node recovers like any durable store
        drop(b);
        let b2 =
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 4, Some(dst.path()))
                .unwrap();
        assert_eq!(b2.len(), a.len());
    }

    #[test]
    fn replicate_apply_validates_before_touching_anything() {
        let src = TempDir::new().unwrap();
        let a =
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, Some(src.path()))
                .unwrap();
        a.insert(sk(1)).unwrap();
        a.compact().unwrap();
        a.insert(sk(2)).unwrap();
        let (snap, wal) = a.replicate_export().unwrap();
        // in-memory joiners work too (the snapshot stream is validated
        // through a scratch file, so there is exactly one decoder)
        let mem = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, None).unwrap();
        assert_eq!(mem.replicate_apply(&snap, &wal).unwrap(), 2);
        assert_eq!(mem.sharded().items(), a.sharded().items());
        // a non-fresh store refuses the bootstrap
        assert!(mem.replicate_apply(&snap, &wal).is_err());
        // in-memory nodes have no durable image to export
        assert!(mem.replicate_export().is_err());
        // torn snapshot / corrupt WAL record / trailing garbage: one
        // clean error each, the joining store left untouched
        let fresh =
            || PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, None).unwrap();
        let torn = &snap[..snap.len() - 3];
        let mut bad_wal = wal.clone();
        bad_wal[9] ^= 0xff;
        let mut trailing = wal.clone();
        trailing.push(0);
        for (s, w) in [
            (torn, &wal[..]),
            (&snap[..], &bad_wal[..]),
            (&snap[..], &trailing[..]),
        ] {
            let store = fresh();
            assert!(store.replicate_apply(s, w).is_err());
            assert!(store.is_empty(), "failed apply must not install anything");
            assert_eq!(store.sharded().next_id(), 0, "no id may be burned");
        }
        // a mismatched stamp is refused, naming both configurations
        let other = PersistentIndex::open(8, SketchScheme::Oph, cfg(), 2, None).unwrap();
        match other.replicate_apply(&snap, &wal) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("cmh") && msg.contains("oph"), "{msg}");
            }
            res => panic!("expected Invalid, got {res:?}"),
        }
    }

    #[test]
    fn mismatched_bits_is_rejected_on_open() {
        let dir = TempDir::new().unwrap();
        {
            let store = PersistentIndex::open_with_bits(
                8,
                SketchScheme::Cmh,
                4,
                cfg(),
                1,
                Some(dir.path()),
            )
            .unwrap();
            store.insert(sk(1)).unwrap();
            store.compact().unwrap();
        }
        // wrong width refuses with an error naming both widths
        match PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 1, Some(dir.path())) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("bits=4") && msg.contains("bits=32"), "{msg}");
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
        // ...and so does a different packed width
        assert!(PersistentIndex::open_with_bits(
            8,
            SketchScheme::Cmh,
            8,
            cfg(),
            1,
            Some(dir.path())
        )
        .is_err());
        // the stamped width still opens
        let store = PersistentIndex::open_with_bits(
            8,
            SketchScheme::Cmh,
            4,
            cfg(),
            1,
            Some(dir.path()),
        )
        .unwrap();
        assert_eq!(store.len(), 1);
    }

    #[test]
    fn legacy_full_width_dirs_refuse_packed_service() {
        // A store persisted at full width (today's default) must not
        // silently serve as a packed store: CMHSNAP2 loads as bits=32
        // and the mismatch is refused.
        let dir = TempDir::new().unwrap();
        {
            let store =
                PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 1, Some(dir.path()))
                    .unwrap();
            store.insert(sk(1)).unwrap();
            store.compact().unwrap();
        }
        match PersistentIndex::open_with_bits(
            8,
            SketchScheme::Cmh,
            1,
            cfg(),
            1,
            Some(dir.path()),
        ) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("bits=32") && msg.contains("bits=1"), "{msg}");
            }
            other => panic!("expected Invalid, got {other:?}"),
        }
        // an abandoned *empty* full-width stamp re-stamps instead
        let fresh = TempDir::new().unwrap();
        drop(
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 1, Some(fresh.path()))
                .unwrap(),
        );
        let store = PersistentIndex::open_with_bits(
            8,
            SketchScheme::Cmh,
            2,
            cfg(),
            1,
            Some(fresh.path()),
        )
        .unwrap();
        assert_eq!(store.stats().bits, 2);
    }

    #[test]
    fn mismatched_k_is_rejected_on_open() {
        let dir = TempDir::new().unwrap();
        {
            let store = PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 1, Some(dir.path())).unwrap();
            store.insert(sk(1)).unwrap();
            store.compact().unwrap();
        }
        assert!(PersistentIndex::open(16, SketchScheme::Cmh, cfg(), 1, Some(dir.path())).is_err());
    }

    #[test]
    fn fresh_dirs_are_scheme_stamped_before_any_wal_record() {
        // Regression for the WAL-only hole: a store that crashed
        // before its first compaction used to carry no scheme stamp at
        // all, so reopening under a different scheme silently replayed
        // incomparable sketches.  Now the stamp is written at first
        // open, before the WAL can hold a record.
        let dir = TempDir::new().unwrap();
        {
            let store = PersistentIndex::open(
                8,
                SketchScheme::Coph,
                cfg(),
                2,
                Some(dir.path()),
            )
            .unwrap();
            store.insert(sk(1)).unwrap();
            // dropped without compacting: snapshot is the empty stamp,
            // the insert lives only in the WAL
        }
        assert!(
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 2, Some(dir.path()))
                .is_err(),
            "WAL-tail-only state must still refuse the wrong scheme"
        );
        let store =
            PersistentIndex::open(8, SketchScheme::Coph, cfg(), 2, Some(dir.path()))
                .unwrap();
        assert_eq!(store.len(), 1, "right scheme recovers the WAL tail");
    }

    #[test]
    fn abandoned_empty_stamps_can_be_restamped() {
        // A mis-started server (opened, stored nothing, died) must not
        // wedge the directory: its stamp pins no data, so reopening
        // under a different scheme — or K — re-stamps instead of
        // demanding a hand-deleted snapshot.bin.
        let dir = TempDir::new().unwrap();
        drop(
            PersistentIndex::open(8, SketchScheme::Coph, cfg(), 2, Some(dir.path()))
                .unwrap(),
        );
        let store =
            PersistentIndex::open(16, SketchScheme::Oph, cfg(), 2, Some(dir.path()))
                .unwrap();
        assert_eq!(store.scheme(), SketchScheme::Oph);
        // once data exists the stamp is binding again
        store.insert((0..16).collect()).unwrap();
        drop(store);
        assert!(
            PersistentIndex::open(16, SketchScheme::Coph, cfg(), 2, Some(dir.path()))
                .is_err(),
            "a record-bearing WAL makes the stamp binding"
        );
        // ...even after compaction folds the records into the snapshot
        let store =
            PersistentIndex::open(16, SketchScheme::Oph, cfg(), 2, Some(dir.path()))
                .unwrap();
        store.compact().unwrap();
        drop(store);
        assert!(
            PersistentIndex::open(16, SketchScheme::Coph, cfg(), 2, Some(dir.path()))
                .is_err()
        );
    }

    #[test]
    fn legacy_wal_only_dirs_are_cmh() {
        // A directory holding WAL records but no snapshot predates
        // scheme stamping (this build stamps before the first append):
        // it was necessarily written under cmh.
        let dir = TempDir::new().unwrap();
        {
            let (mut wal, records) = Wal::open(&dir.path().join(WAL_FILE)).unwrap();
            assert!(records.is_empty());
            wal.append(&WalRecord::Insert {
                id: 0,
                sketch: sk(1),
            })
            .unwrap();
        }
        match PersistentIndex::open(8, SketchScheme::Oph, cfg(), 1, Some(dir.path())) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("legacy") && msg.contains("cmh"), "{msg}")
            }
            Err(other) => panic!("expected Invalid, got {other:?}"),
            Ok(_) => panic!("legacy WAL-only dir must refuse non-cmh schemes"),
        }
        let store =
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 1, Some(dir.path()))
                .unwrap();
        assert_eq!(store.len(), 1);
        assert_eq!(store.sketch(0), Some(sk(1)));
    }

    #[test]
    fn mismatched_scheme_is_rejected_on_open() {
        let dir = TempDir::new().unwrap();
        {
            let store =
                PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 1, Some(dir.path()))
                    .unwrap();
            assert_eq!(store.scheme(), SketchScheme::Cmh);
            store.insert(sk(1)).unwrap();
            store.compact().unwrap();
        }
        // the snapshot is stamped 'cmh'; opening under 'coph' must fail
        // with an error naming both schemes
        match PersistentIndex::open(
            8,
            SketchScheme::Coph,
            cfg(),
            1,
            Some(dir.path()),
        ) {
            Err(crate::Error::Invalid(msg)) => {
                assert!(msg.contains("cmh") && msg.contains("coph"), "{msg}");
            }
            Err(other) => panic!("expected Invalid, got {other:?}"),
            Ok(_) => panic!("mismatched scheme must not open"),
        }
        // the matching scheme still opens fine
        let store =
            PersistentIndex::open(8, SketchScheme::Cmh, cfg(), 1, Some(dir.path()))
                .unwrap();
        assert_eq!(store.len(), 1);
    }
}
