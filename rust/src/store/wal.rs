//! Append-only write-ahead log for the sketch store.
//!
//! One length-prefixed binary record per mutation, std only:
//!
//! ```text
//! record  := len:u32le | crc:u32le | payload (len bytes)
//! payload := 0x01 | id:u64le | k:u32le | k × u32le   (insert)
//!          | 0x02 | id:u64le                          (delete)
//! ```
//!
//! `crc` is FNV-1a over the payload.  On open, the valid prefix is
//! replayed and any torn tail (short record, bad checksum, bad tag —
//! the signature of a crash mid-append) is truncated away so the log
//! is always well-formed for the next append.  Appends reach the OS
//! (`write_all`) on every call, so recovery survives a process crash;
//! power-loss durability is provided by [`super::Snapshot`] at
//! compaction time, which fsyncs.

use crate::util::fnv::fnv1a32;
use std::fs::OpenOptions;
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

/// One logged mutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// Insert `id` with its sketch.
    Insert {
        /// Item id.
        id: u64,
        /// K hash values.
        sketch: Vec<u32>,
    },
    /// Delete `id`.
    Delete {
        /// Item id.
        id: u64,
    },
}

const TAG_INSERT: u8 = 1;
const TAG_DELETE: u8 = 2;

fn encode(rec: &WalRecord) -> Vec<u8> {
    let mut payload = Vec::new();
    match rec {
        WalRecord::Insert { id, sketch } => {
            payload.push(TAG_INSERT);
            payload.extend_from_slice(&id.to_le_bytes());
            payload.extend_from_slice(&(sketch.len() as u32).to_le_bytes());
            for v in sketch {
                payload.extend_from_slice(&v.to_le_bytes());
            }
        }
        WalRecord::Delete { id } => {
            payload.push(TAG_DELETE);
            payload.extend_from_slice(&id.to_le_bytes());
        }
    }
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

fn read_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn read_u64(b: &[u8], off: usize) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[off..off + 8]);
    u64::from_le_bytes(a)
}

fn decode_payload(p: &[u8]) -> Option<WalRecord> {
    match p.first()? {
        &TAG_INSERT => {
            if p.len() < 1 + 8 + 4 {
                return None;
            }
            let id = read_u64(p, 1);
            let k = read_u32(p, 9) as usize;
            if p.len() != 1 + 8 + 4 + 4 * k {
                return None;
            }
            let sketch = (0..k).map(|i| read_u32(p, 13 + 4 * i)).collect();
            Some(WalRecord::Insert { id, sketch })
        }
        &TAG_DELETE => {
            if p.len() != 1 + 8 {
                return None;
            }
            Some(WalRecord::Delete { id: read_u64(p, 1) })
        }
        _ => None,
    }
}

/// Scan the valid record prefix of raw log bytes; returns the decoded
/// records and the byte length of that prefix.
fn scan(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut recs = Vec::new();
    let mut off = 0usize;
    loop {
        if bytes.len() - off < 8 {
            break;
        }
        let len = read_u32(bytes, off) as usize;
        let crc = read_u32(bytes, off + 4);
        if bytes.len() - off - 8 < len {
            break;
        }
        let payload = &bytes[off + 8..off + 8 + len];
        if fnv1a32(payload) != crc {
            break;
        }
        match decode_payload(payload) {
            Some(rec) => recs.push(rec),
            None => break,
        }
        off += 8 + len;
    }
    (recs, off)
}

/// An open write-ahead log positioned for append.
#[derive(Debug)]
pub struct Wal {
    file: std::fs::File,
    bytes: u64,
}

impl Wal {
    /// Open `path` (creating it if absent), replay the valid record
    /// prefix, truncate any torn tail, and return the log positioned
    /// for append together with the replayed records (oldest first).
    pub fn open(path: &Path) -> crate::Result<(Wal, Vec<WalRecord>)> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };
        let (recs, valid) = scan(&bytes);
        let mut file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path)?;
        file.set_len(valid as u64)?;
        file.seek(SeekFrom::End(0))?;
        Ok((
            Wal {
                file,
                bytes: valid as u64,
            },
            recs,
        ))
    }

    /// Append one record (reaches the OS before returning).  On a
    /// failed (possibly partial) write the file is restored to the
    /// clean record prefix, so a later successful append can never
    /// land behind torn bytes — which recovery would otherwise treat
    /// as the end of the log, silently discarding those records.
    pub fn append(&mut self, rec: &WalRecord) -> crate::Result<()> {
        let buf = encode(rec);
        if let Err(e) = self.file.write_all(&buf) {
            let _ = self.file.set_len(self.bytes);
            let _ = self.file.seek(SeekFrom::End(0));
            return Err(e.into());
        }
        self.bytes += buf.len() as u64;
        Ok(())
    }

    /// Flush the log all the way to disk (fsync).
    pub fn sync(&mut self) -> crate::Result<()> {
        self.file.sync_data()?;
        Ok(())
    }

    /// Truncate the log to empty (after its records have been folded
    /// into a snapshot).
    pub fn reset(&mut self) -> crate::Result<()> {
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.bytes = 0;
        Ok(())
    }

    /// Current log size in bytes.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;

    fn sample() -> Vec<WalRecord> {
        vec![
            WalRecord::Insert {
                id: 0,
                sketch: vec![1, 2, 3, 4],
            },
            WalRecord::Delete { id: 0 },
            WalRecord::Insert {
                id: 1,
                sketch: vec![9, 8, 7, 6],
            },
        ]
    }

    #[test]
    fn append_and_replay_roundtrip() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        {
            let (mut wal, recs) = Wal::open(&path).unwrap();
            assert!(recs.is_empty());
            for r in sample() {
                wal.append(&r).unwrap();
            }
            assert!(wal.bytes() > 0);
            wal.sync().unwrap();
        }
        let (wal, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs, sample());
        assert_eq!(wal.bytes(), std::fs::metadata(&path).unwrap().len());
    }

    #[test]
    fn torn_tail_is_truncated_and_appendable() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            for r in sample() {
                wal.append(&r).unwrap();
            }
        }
        let clean_len = std::fs::metadata(&path).unwrap().len();
        // simulate a crash mid-append: garbage half-record at the tail
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0x20, 0x00, 0x00, 0x00, 0xde, 0xad]).unwrap();
        }
        let (mut wal, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs, sample(), "valid prefix survives the torn tail");
        assert_eq!(wal.bytes(), clean_len, "tail truncated");
        wal.append(&WalRecord::Delete { id: 42 }).unwrap();
        drop(wal);
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs.len(), sample().len() + 1);
        assert_eq!(*recs.last().unwrap(), WalRecord::Delete { id: 42 });
    }

    #[test]
    fn corrupt_crc_stops_replay() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            for r in sample() {
                wal.append(&r).unwrap();
            }
        }
        // flip a payload byte inside the second record
        let mut bytes = std::fs::read(&path).unwrap();
        let first_len = 8 + read_u32(&bytes, 0) as usize;
        let target = first_len + 9; // inside record 2's payload
        bytes[target] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs.len(), 1, "replay stops at the corrupt record");
        assert_eq!(recs[0], sample()[0]);
    }

    #[test]
    fn reset_empties_the_log() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.reset().unwrap();
        assert_eq!(wal.bytes(), 0);
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        drop(wal);
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 2 }]);
    }
}
