//! Append-only write-ahead log for the sketch store.
//!
//! One length-prefixed binary record per mutation, std only:
//!
//! ```text
//! record  := len:u32le | crc:u32le | payload (len bytes)
//! payload := 0x01 | id:u64le | k:u32le | k × u32le   (insert)
//!          | 0x02 | id:u64le                          (delete)
//!          | 0x03 | n:u32le | n × item                (insert batch)
//!          | 0x04 | bits:u8 | n:u32le | n × pitem     (packed insert)
//! item    := id:u64le | k:u32le | k × u32le
//! pitem   := id:u64le | k:u32le | W × u64le           W = ceil(k·bits/64)
//! ```
//!
//! A batched insert is **one** record under **one** checksum, which is
//! what makes `insert_batch` all-or-nothing across crashes: a torn
//! write fails the CRC and the whole batch is truncated away on open —
//! there is no recovery state in which only some rows of a batch are
//! durable.
//!
//! `crc` is FNV-1a over the payload.  On open, the valid prefix is
//! replayed and any torn tail (short record, bad checksum, bad tag —
//! the signature of a crash mid-append) is truncated away so the log
//! is always well-formed for the next append.  Appends reach the OS
//! (`write_all`) on every call, so recovery survives a process crash;
//! power-loss durability is provided by [`super::Snapshot`] at
//! compaction time, which fsyncs.

use crate::sketch::{pack_row, packed_words, unpack_row};
use crate::util::fnv::fnv1a32;
use std::fs::OpenOptions;
use std::io::{Seek, SeekFrom, Write};
use std::path::Path;

/// One logged mutation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WalRecord {
    /// Insert `id` with its sketch.
    Insert {
        /// Item id.
        id: u64,
        /// K hash values.
        sketch: Vec<u32>,
    },
    /// Delete `id`.
    Delete {
        /// Item id.
        id: u64,
    },
    /// Insert a whole batch of `(id, sketch)` rows as one record —
    /// one checksum, so a crash mid-write durably keeps either every
    /// row or none (torn-tail truncation on open).
    InsertBatch {
        /// `(id, sketch)` per row.
        items: Vec<(u64, Vec<u32>)>,
    },
    /// The packed-plane insert record: rows are logged as the same
    /// `bits`-wide bit-packed words the store serves from (≈ 32/b×
    /// smaller than [`WalRecord::InsertBatch`]).  Sketch values here
    /// are the *masked* low-`bits` lanes — the codec packs on encode
    /// and unpacks on decode.  Same single-checksum atomicity as the
    /// full-width batch record; a singleton insert is an n = 1 batch.
    InsertPacked {
        /// Bits stored per hash (< 32; must divide 64).
        bits: u8,
        /// `(id, masked sketch)` per row.
        items: Vec<(u64, Vec<u32>)>,
    },
}

const TAG_INSERT: u8 = 1;
const TAG_DELETE: u8 = 2;
const TAG_INSERT_BATCH: u8 = 3;
const TAG_INSERT_PACKED: u8 = 4;

fn push_item(payload: &mut Vec<u8>, id: u64, sketch: &[u32]) {
    payload.extend_from_slice(&id.to_le_bytes());
    payload.extend_from_slice(&(sketch.len() as u32).to_le_bytes());
    for v in sketch {
        payload.extend_from_slice(&v.to_le_bytes());
    }
}

fn encode(rec: &WalRecord) -> Vec<u8> {
    let mut payload = Vec::new();
    match rec {
        WalRecord::Insert { id, sketch } => {
            payload.push(TAG_INSERT);
            push_item(&mut payload, *id, sketch);
        }
        WalRecord::Delete { id } => {
            payload.push(TAG_DELETE);
            payload.extend_from_slice(&id.to_le_bytes());
        }
        WalRecord::InsertBatch { items } => {
            payload.push(TAG_INSERT_BATCH);
            payload.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for (id, sketch) in items {
                push_item(&mut payload, *id, sketch);
            }
        }
        WalRecord::InsertPacked { bits, items } => {
            payload.push(TAG_INSERT_PACKED);
            payload.push(*bits);
            payload.extend_from_slice(&(items.len() as u32).to_le_bytes());
            for (id, sketch) in items {
                payload.extend_from_slice(&id.to_le_bytes());
                payload.extend_from_slice(&(sketch.len() as u32).to_le_bytes());
                let mut row = vec![0u64; packed_words(sketch.len(), *bits)];
                pack_row(sketch, *bits, &mut row);
                for w in &row {
                    payload.extend_from_slice(&w.to_le_bytes());
                }
            }
        }
    }
    let mut out = Vec::with_capacity(8 + payload.len());
    out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
    out.extend_from_slice(&fnv1a32(&payload).to_le_bytes());
    out.extend_from_slice(&payload);
    out
}

fn read_u32(b: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([b[off], b[off + 1], b[off + 2], b[off + 3]])
}

fn read_u64(b: &[u8], off: usize) -> u64 {
    let mut a = [0u8; 8];
    a.copy_from_slice(&b[off..off + 8]);
    u64::from_le_bytes(a)
}

/// Decode one `id | k | k×u32` item at `off`; returns the item and
/// the offset just past it, or `None` on a short buffer.
fn read_item(p: &[u8], off: usize) -> Option<((u64, Vec<u32>), usize)> {
    if p.len() < off + 8 + 4 {
        return None;
    }
    let id = read_u64(p, off);
    let k = read_u32(p, off + 8) as usize;
    let end = off + 12 + 4 * k;
    if p.len() < end {
        return None;
    }
    let sketch = (0..k).map(|i| read_u32(p, off + 12 + 4 * i)).collect();
    Some(((id, sketch), end))
}

/// Decode one `id | k | W×u64` packed item at `off`; returns the item
/// (lanes unpacked to masked values) and the offset just past it, or
/// `None` on a short buffer.
fn read_packed_item(p: &[u8], off: usize, bits: u8) -> Option<((u64, Vec<u32>), usize)> {
    if p.len() < off + 8 + 4 {
        return None;
    }
    let id = read_u64(p, off);
    let k = read_u32(p, off + 8) as usize;
    let wpr = packed_words(k, bits);
    let end = off.checked_add(12)?.checked_add(8usize.checked_mul(wpr)?)?;
    if p.len() < end {
        return None;
    }
    let row: Vec<u64> = (0..wpr).map(|i| read_u64(p, off + 12 + 8 * i)).collect();
    Some(((id, unpack_row(&row, k, bits)), end))
}

fn decode_payload(p: &[u8]) -> Option<WalRecord> {
    match p.first()? {
        &TAG_INSERT => {
            let ((id, sketch), end) = read_item(p, 1)?;
            if p.len() != end {
                return None;
            }
            Some(WalRecord::Insert { id, sketch })
        }
        &TAG_DELETE => {
            if p.len() != 1 + 8 {
                return None;
            }
            Some(WalRecord::Delete { id: read_u64(p, 1) })
        }
        &TAG_INSERT_BATCH => {
            if p.len() < 1 + 4 {
                return None;
            }
            let n = read_u32(p, 1) as usize;
            // Every item needs at least 12 bytes; a count the payload
            // cannot possibly hold is corruption — reject it before
            // trusting it as an allocation size.
            if n > (p.len() - 5) / 12 {
                return None;
            }
            let mut items = Vec::with_capacity(n);
            let mut off = 5;
            for _ in 0..n {
                let (item, next) = read_item(p, off)?;
                items.push(item);
                off = next;
            }
            if p.len() != off {
                return None;
            }
            Some(WalRecord::InsertBatch { items })
        }
        &TAG_INSERT_PACKED => {
            if p.len() < 1 + 1 + 4 {
                return None;
            }
            let bits = p[1];
            // Only the packed widths are legal on disk; anything else
            // is corruption (a full-width insert uses tags 1/3).
            if crate::sketch::check_sketch_bits(bits).is_err() || bits == 32 {
                return None;
            }
            let n = read_u32(p, 2) as usize;
            // Every packed item needs at least 12 bytes; a count the
            // payload cannot possibly hold is corruption — reject it
            // before trusting it as an allocation size.
            if n > (p.len() - 6) / 12 {
                return None;
            }
            let mut items = Vec::with_capacity(n);
            let mut off = 6;
            for _ in 0..n {
                let (item, next) = read_packed_item(p, off, bits)?;
                items.push(item);
                off = next;
            }
            if p.len() != off {
                return None;
            }
            Some(WalRecord::InsertPacked { bits, items })
        }
        _ => None,
    }
}

/// Scan the valid record prefix of raw log bytes; returns the decoded
/// records and the byte length of that prefix.
fn scan(bytes: &[u8]) -> (Vec<WalRecord>, usize) {
    let mut recs = Vec::new();
    let mut off = 0usize;
    loop {
        if bytes.len() - off < 8 {
            break;
        }
        let len = read_u32(bytes, off) as usize;
        let crc = read_u32(bytes, off + 4);
        if bytes.len() - off - 8 < len {
            break;
        }
        let payload = &bytes[off + 8..off + 8 + len];
        if fnv1a32(payload) != crc {
            break;
        }
        match decode_payload(payload) {
            Some(rec) => recs.push(rec),
            None => break,
        }
        off += 8 + len;
    }
    (recs, off)
}

/// An open write-ahead log positioned for append.
#[derive(Debug)]
pub struct Wal {
    file: std::fs::File,
    bytes: u64,
}

impl Wal {
    /// Decode a complete WAL image: every byte must belong to a valid
    /// record.  Returns `None` on a torn tail, a corrupt checksum, or
    /// trailing garbage — the replication path uses this to refuse a
    /// peer's WAL stream unless it is wholly intact, unlike recovery
    /// ([`Wal::open`]), which keeps the valid prefix of its *own* log
    /// because a torn tail there is the expected signature of a crash
    /// mid-append rather than a transport fault.
    pub fn decode_all(bytes: &[u8]) -> Option<Vec<WalRecord>> {
        let (recs, consumed) = scan(bytes);
        (consumed == bytes.len()).then_some(recs)
    }
}

impl Wal {
    /// Open `path` (creating it if absent), replay the valid record
    /// prefix, truncate any torn tail, and return the log positioned
    /// for append together with the replayed records (oldest first).
    pub fn open(path: &Path) -> crate::Result<(Wal, Vec<WalRecord>)> {
        let bytes = match std::fs::read(path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e.into()),
        };
        let (recs, valid) = scan(&bytes);
        let mut file = OpenOptions::new()
            .create(true)
            .read(true)
            .write(true)
            .open(path)?;
        file.set_len(valid as u64)?;
        file.seek(SeekFrom::End(0))?;
        Ok((
            Wal {
                file,
                bytes: valid as u64,
            },
            recs,
        ))
    }

    /// Append one record (reaches the OS before returning).  On a
    /// failed (possibly partial) write the file is restored to the
    /// clean record prefix, so a later successful append can never
    /// land behind torn bytes — which recovery would otherwise treat
    /// as the end of the log, silently discarding those records.
    pub fn append(&mut self, rec: &WalRecord) -> crate::Result<()> {
        let buf = encode(rec);
        if let Err(e) = self.file.write_all(&buf) {
            let _ = self.file.set_len(self.bytes);
            let _ = self.file.seek(SeekFrom::End(0));
            return Err(e.into());
        }
        self.bytes += buf.len() as u64;
        Ok(())
    }

    /// Flush the log all the way to disk (fsync).
    pub fn sync(&mut self) -> crate::Result<()> {
        self.file.sync_data()?;
        Ok(())
    }

    /// Truncate the log to empty (after its records have been folded
    /// into a snapshot).
    pub fn reset(&mut self) -> crate::Result<()> {
        self.file.set_len(0)?;
        self.file.seek(SeekFrom::Start(0))?;
        self.bytes = 0;
        Ok(())
    }

    /// Current log size in bytes.
    pub fn bytes(&self) -> u64 {
        self.bytes
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;
    use crate::util::testutil::TempDir;

    fn sample() -> Vec<WalRecord> {
        vec![
            WalRecord::Insert {
                id: 0,
                sketch: vec![1, 2, 3, 4],
            },
            WalRecord::Delete { id: 0 },
            WalRecord::Insert {
                id: 1,
                sketch: vec![9, 8, 7, 6],
            },
        ]
    }

    #[test]
    fn append_and_replay_roundtrip() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        {
            let (mut wal, recs) = Wal::open(&path).unwrap();
            assert!(recs.is_empty());
            for r in sample() {
                wal.append(&r).unwrap();
            }
            assert!(wal.bytes() > 0);
            wal.sync().unwrap();
        }
        let (wal, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs, sample());
        assert_eq!(wal.bytes(), std::fs::metadata(&path).unwrap().len());
    }

    #[test]
    fn torn_tail_is_truncated_and_appendable() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            for r in sample() {
                wal.append(&r).unwrap();
            }
        }
        let clean_len = std::fs::metadata(&path).unwrap().len();
        // simulate a crash mid-append: garbage half-record at the tail
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(&[0x20, 0x00, 0x00, 0x00, 0xde, 0xad]).unwrap();
        }
        let (mut wal, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs, sample(), "valid prefix survives the torn tail");
        assert_eq!(wal.bytes(), clean_len, "tail truncated");
        wal.append(&WalRecord::Delete { id: 42 }).unwrap();
        drop(wal);
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs.len(), sample().len() + 1);
        assert_eq!(*recs.last().unwrap(), WalRecord::Delete { id: 42 });
    }

    #[test]
    fn corrupt_crc_stops_replay() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            for r in sample() {
                wal.append(&r).unwrap();
            }
        }
        // flip a payload byte inside the second record
        let mut bytes = std::fs::read(&path).unwrap();
        let first_len = 8 + read_u32(&bytes, 0) as usize;
        let target = first_len + 9; // inside record 2's payload
        bytes[target] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs.len(), 1, "replay stops at the corrupt record");
        assert_eq!(recs[0], sample()[0]);
    }

    #[test]
    fn insert_batch_record_is_atomic_under_torn_writes() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        let batch = WalRecord::InsertBatch {
            items: vec![(0, vec![1, 2, 3, 4]), (1, vec![9, 8, 7, 6]), (2, vec![5; 4])],
        };
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            wal.append(&WalRecord::Delete { id: 9 }).unwrap();
            wal.append(&batch).unwrap();
        }
        // full record replays as one unit
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 9 }, batch.clone()]);
        // a crash mid-batch-write (torn tail anywhere inside the
        // record) durably keeps NONE of the batch rows: cut the
        // original file inside the batch record and reopen.  (Wal::open
        // truncates on open, so restore the full image before each cut.)
        let original = std::fs::read(&path).unwrap();
        let full = original.len();
        for cut in [full - 1, full - 7, full - 20] {
            std::fs::write(&path, &original[..cut]).unwrap();
            let (_, recs) = Wal::open(&path).unwrap();
            assert_eq!(
                recs,
                vec![WalRecord::Delete { id: 9 }],
                "cut at {cut}: partial batch must not replay"
            );
        }
    }

    #[test]
    fn insert_packed_record_roundtrips_and_shrinks() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        // masked values (lanes already < 2^bits) roundtrip exactly
        let rows: Vec<(u64, Vec<u32>)> = (0..4u64)
            .map(|id| (id, (0..37u32).map(|i| (id as u32 + i) % 16).collect()))
            .collect();
        let packed = WalRecord::InsertPacked {
            bits: 4,
            items: rows.clone(),
        };
        let full = WalRecord::InsertBatch { items: rows };
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            wal.append(&packed).unwrap();
            let packed_bytes = wal.bytes();
            wal.append(&full).unwrap();
            let full_bytes = wal.bytes() - packed_bytes;
            assert!(
                packed_bytes < full_bytes,
                "packed record {packed_bytes} B must beat full {full_bytes} B"
            );
        }
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs[0], packed);
        // encoding masks: unmasked input decodes to its masked lanes
        let noisy = WalRecord::InsertPacked {
            bits: 4,
            items: vec![(9, vec![0xffu32, 3, 16, 15])],
        };
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(&noisy).unwrap();
        drop(wal);
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(
            *recs.last().unwrap(),
            WalRecord::InsertPacked {
                bits: 4,
                items: vec![(9, vec![15, 3, 0, 15])],
            }
        );
    }

    #[test]
    fn torn_packed_record_is_atomic_and_bad_bits_stop_replay() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        let packed = WalRecord::InsertPacked {
            bits: 8,
            items: vec![(0, vec![1; 16]), (1, vec![2; 16])],
        };
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            wal.append(&WalRecord::Delete { id: 5 }).unwrap();
            wal.append(&packed).unwrap();
        }
        let original = std::fs::read(&path).unwrap();
        // any cut inside the packed record keeps none of its rows
        for cut in [original.len() - 1, original.len() - 9, original.len() - 20] {
            std::fs::write(&path, &original[..cut]).unwrap();
            let (_, recs) = Wal::open(&path).unwrap();
            assert_eq!(recs, vec![WalRecord::Delete { id: 5 }], "cut at {cut}");
        }
        // a corrupt bits byte fails the CRC; and even with a recomputed
        // CRC an illegal width is rejected by the decoder
        let mut bytes = original.clone();
        let first_len = 8 + read_u32(&bytes, 0) as usize;
        let bits_at = first_len + 8 + 1; // second record: len|crc|tag|bits
        assert_eq!(bytes[bits_at], 8);
        bytes[bits_at] = 7; // 7 is not a legal width
        let payload_len = read_u32(&bytes, first_len) as usize;
        let crc = fnv1a32(&bytes[first_len + 8..first_len + 8 + payload_len]);
        bytes[first_len + 4..first_len + 8].copy_from_slice(&crc.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 5 }], "bad width rejected");
    }

    #[test]
    fn decode_all_accepts_only_whole_images() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        {
            let (mut wal, _) = Wal::open(&path).unwrap();
            for r in sample() {
                wal.append(&r).unwrap();
            }
        }
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(Wal::decode_all(&bytes).unwrap(), sample());
        assert_eq!(Wal::decode_all(&[]).unwrap(), vec![]);
        // a torn tail is a valid *prefix* for recovery but not a valid
        // whole image for replication
        assert!(Wal::decode_all(&bytes[..bytes.len() - 1]).is_none());
        // a flipped payload byte fails the record CRC
        let mut flipped = bytes.clone();
        flipped[9] ^= 0xff;
        assert!(Wal::decode_all(&flipped).is_none());
        // trailing garbage after the last record is refused
        let mut trailing = bytes.clone();
        trailing.extend_from_slice(&[0u8; 3]);
        assert!(Wal::decode_all(&trailing).is_none());
    }

    #[test]
    fn reset_empties_the_log() {
        let dir = TempDir::new().unwrap();
        let path = dir.path().join("wal.log");
        let (mut wal, _) = Wal::open(&path).unwrap();
        wal.append(&WalRecord::Delete { id: 1 }).unwrap();
        wal.reset().unwrap();
        assert_eq!(wal.bytes(), 0);
        wal.append(&WalRecord::Delete { id: 2 }).unwrap();
        drop(wal);
        let (_, recs) = Wal::open(&path).unwrap();
        assert_eq!(recs, vec![WalRecord::Delete { id: 2 }]);
    }
}
