//! `cminhash` CLI — leader entrypoint.
//!
//! Subcommands:
//! * `serve`    — run the sketching/similarity server (XLA or Rust engine)
//! * `load`     — bulk-ingest a JSONL vector file through `insert_batch`
//! * `compact`  — fold a persist directory's WAL into a fresh snapshot
//! * `figures`  — regenerate the paper's Figures 2–7 as CSV
//! * `dataset`  — generate the §4.2 corpus stand-ins
//! * `sketch`   — offline batch sketching of a dataset file
//! * `loadgen`  — drive a running server and report latency/throughput
//! * `stats`    — fetch a running server's stats (JSON or Prometheus text)
//! * `top`      — live dashboard: per-op request rates + latency percentiles
//! * `info`     — list compiled artifact variants
//! * `theory`   — evaluate the paper's exact variance formulas
//!
//! Flags are parsed by the in-tree `Args` helper, and errors flow
//! through the crate's own [`cminhash::Error`] — the binary has zero
//! external dependencies (no clap, no anyhow).

// Same discipline as the library crate root (see clippy.toml).
#![warn(clippy::disallowed_methods, clippy::disallowed_macros)]

use cminhash::config::{EngineKind, ServeConfig};
use cminhash::coordinator::Coordinator;
use cminhash::data::{BinaryDataset, CorpusKind};
use cminhash::index::IndexConfig;
use cminhash::runtime::Manifest;
use cminhash::store::{resolve_shards, PersistentIndex};
use cminhash::server::{BlockingClient, Server};
use cminhash::sketch::{SketchScheme, Sketcher};
use cminhash::util::rng::Rng;
use cminhash::{Error, Result};
use std::collections::HashMap;
use std::path::PathBuf;
use std::time::Instant;

const USAGE: &str = "\
cminhash — C-MinHash sketching & similarity-search service

USAGE:
  cminhash serve   [--config FILE.json] [--addr A] [--engine xla|rust]
                   [--scheme classic|cmh|zero-pi|oph|coph|iuh]
                   [--bits 1|2|4|8|16|32]
                   [--dim D] [--num-hashes K] [--artifacts DIR] [--seed S]
                   [--shards N] [--persist DIR] [--max-conns N]
  cminhash load    FILE.jsonl [--addr A] [--batch N] [--binary]
                   [--cluster CLUSTER.json]
                   (bulk-ingest: one {\"dim\":D,\"indices\":[...]} object
                   per line, streamed through insert_batch; --binary
                   negotiates bin1 framing and ships client-sketched
                   packed rows instead; --cluster routes each row to
                   its rendezvous owner across the listed nodes)
  cminhash compact [--config FILE.json] [--dir DIR] [--num-hashes K]
                   [--scheme S] [--bits B] [--shards N]
                   (offline only — use the `save` wire op to compact
                   under a running server)
  cminhash figures (--all | --fig N) [--out DIR] [--fast]
  cminhash dataset --kind nips|bbc|mnist|cifar --out FILE.json
                   [--n N] [--seed S] [--stats]
  cminhash sketch  --input FILE.json --out FILE.json
                   [--num-hashes K] [--seed S] [--scheme S] [--bits B]
  cminhash loadgen [--addr A] [--requests N] [--dim D] [--nnz F] [--conns C]
                   [--binary]   (drive sketch ops over bin1 frames)
                   [--cluster CLUSTER.json] [--batch N] [--topk K]
                   (cluster mode: ingest N synthetic rows through
                   rendezvous-routed insert_batch, then fan-out
                   queries; reports rows/s, query latency, degraded
                   nodes and the node_errors counter)
  cminhash stats   [--addr A] [--prom]
                   (one stats snapshot: JSON by default, --prom prints
                   the Prometheus text exposition)
  cminhash top     [--addr A] [--interval-ms MS] [--iters N]
                   (poll a running server: per-op request-rate deltas
                   and latency percentiles, one line per tick;
                   --iters 0 = run until interrupted)
  cminhash info    [--artifacts DIR]
  cminhash theory  --d D --f F [--a A] [--k K]
";

/// Build the CLI's uniform error type (everything is user input here).
fn usage_err(msg: impl Into<String>) -> Error {
    Error::Invalid(msg.into())
}

/// Tiny `--flag value` / `--flag` parser.
struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let is_bool = matches!(name, "stats" | "fast" | "all" | "binary" | "prom");
                if is_bool {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                } else {
                    let v = argv
                        .get(i + 1)
                        .ok_or_else(|| usage_err(format!("--{name} needs a value")))?;
                    flags.insert(name.to_string(), v.clone());
                    i += 2;
                }
            } else {
                return Err(usage_err(format!("unexpected argument {a:?}")));
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| usage_err(format!("--{name} required")))
    }

    fn get_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| usage_err(format!("bad --{name} {v:?}: {e}"))),
        }
    }

    fn require_parsed<T: std::str::FromStr>(&self, name: &str) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        self.get_parsed(name)?
            .ok_or_else(|| usage_err(format!("--{name} required")))
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let Some(cmd) = argv.first() else {
        eprint!("{USAGE}");
        std::process::exit(2);
    };
    // `load` takes its file as a positional argument; peel it off
    // before the flag parser (which accepts only --flags).
    let mut positional: Option<String> = None;
    let mut flag_args = &argv[1..];
    if cmd == "load" {
        if let Some(first) = flag_args.first() {
            if !first.starts_with("--") {
                positional = Some(first.clone());
                flag_args = &argv[2..];
            }
        }
    }
    let args = Args::parse(flag_args)?;
    match cmd.as_str() {
        "serve" => cmd_serve(&args),
        "load" => cmd_load(&args, positional),
        "compact" => cmd_compact(&args),
        "figures" => cmd_figures(&args),
        "dataset" => cmd_dataset(&args),
        "sketch" => cmd_sketch(&args),
        "loadgen" => cmd_loadgen(&args),
        "stats" => cmd_stats(&args),
        "top" => cmd_top(&args),
        "info" => cmd_info(&args),
        "theory" => cmd_theory(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => Err(usage_err(format!("unknown command {other:?}\n{USAGE}"))),
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(p) => ServeConfig::from_file(std::path::Path::new(p))?,
        None => ServeConfig::default(),
    };
    if let Some(a) = args.get("addr") {
        cfg.addr = a.to_string();
    }
    if let Some(e) = args.get("engine") {
        cfg.engine = EngineKind::parse(e)?;
    }
    if let Some(s) = args.get("scheme") {
        cfg.sketch.scheme = SketchScheme::parse(s)?;
    }
    if let Some(b) = args.get_parsed::<u8>("bits")? {
        cfg.sketch.bits = b;
    }
    if let Some(d) = args.get_parsed::<usize>("dim")? {
        cfg.dim = d;
    }
    if let Some(k) = args.get_parsed::<usize>("num-hashes")? {
        cfg.num_hashes = k;
    }
    if let Some(p) = args.get("artifacts") {
        cfg.artifacts_dir = PathBuf::from(p);
    }
    if let Some(s) = args.get_parsed::<u64>("seed")? {
        cfg.seed = s;
    }
    if let Some(s) = args.get_parsed::<usize>("shards")? {
        cfg.store.shards = s;
    }
    if let Some(p) = args.get("persist") {
        cfg.store.persist_dir = Some(PathBuf::from(p));
    }
    if let Some(c) = args.get_parsed::<usize>("max-conns")? {
        cfg.server.max_connections = c;
    }
    cfg.validate()?;
    let svc = Coordinator::start(cfg.clone())?;
    let server = Server::spawn(svc.clone(), &cfg.addr)?;
    let (_, store) = svc.stats();
    println!(
        "serving on {} (engine={:?}, scheme={}, bits={}, D={}, K={}, shards={}, \
         max-conns={})",
        server.addr(),
        cfg.engine,
        cfg.sketch.scheme,
        cfg.sketch.bits,
        cfg.dim,
        cfg.num_hashes,
        store.shards.len(),
        cfg.server.max_connections,
    );
    match &cfg.store.persist_dir {
        Some(dir) => println!(
            "persistence: {} (recovered {} sketches, {} bytes on disk)",
            dir.display(),
            store.stored,
            store.persisted_bytes
        ),
        None => println!("persistence: off (sketches die with the process)"),
    }
    server.join_forever();
}

/// Bulk-ingest a JSONL vector file into a running server through
/// `insert_batch` round-trips, with periodic progress/throughput
/// lines.  The file is `cminhash load FILE.jsonl` (positional) or
/// `--input FILE.jsonl`.
fn cmd_load(args: &Args, positional: Option<String>) -> Result<()> {
    let file = match positional.or_else(|| args.get("input").map(String::from)) {
        Some(f) => PathBuf::from(f),
        None => return Err(usage_err("load needs a FILE.jsonl (or --input FILE)")),
    };
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878").to_string();
    let batch = args.get_parsed::<usize>("batch")?.unwrap_or(512);
    if batch == 0 {
        return Err(usage_err("--batch must be > 0"));
    }
    let binary = args.has("binary");
    let cluster = match args.get("cluster") {
        Some(p) => Some(cminhash::server::ClusterConfig::load(std::path::Path::new(p))?),
        None => None,
    };
    if binary && cluster.is_some() {
        return Err(usage_err("--binary and --cluster are mutually exclusive"));
    }
    match &cluster {
        Some(cfg) => println!(
            "loading {} across {} cluster nodes ({batch} rows per chunk)",
            file.display(),
            cfg.nodes.len()
        ),
        None => println!(
            "loading {} into {addr} ({batch} rows per {})",
            file.display(),
            if binary {
                "insert_packed frame (bin1)"
            } else {
                "insert_batch"
            }
        ),
    }
    // Print a progress line roughly every 8 batches so multi-million
    // row ingests show a heartbeat without drowning the terminal.
    let mut last_printed = 0u64;
    let progress = |r: &cminhash::server::LoadReport| {
        if r.batches - last_printed >= 8 {
            last_printed = r.batches;
            println!(
                "  {} rows in {} batches ({:.0} rows/s)",
                r.rows,
                r.batches,
                r.rows_per_sec()
            );
        }
    };
    let report = if let Some(cfg) = cluster {
        cminhash::server::load_jsonl_cluster(cfg, &file, batch, progress)?
    } else if binary {
        cminhash::server::load_jsonl_binary(&addr, &file, batch, progress)?
    } else {
        cminhash::server::load_jsonl(&addr, &file, batch, progress)?
    };
    println!(
        "loaded {} rows in {} batches over {:.2}s -> {:.0} rows/s",
        report.rows,
        report.batches,
        report.secs,
        report.rows_per_sec()
    );
    Ok(())
}

/// Fold a persist directory's WAL into a fresh snapshot.  Recovery at
/// `serve` startup replays the WAL anyway; compacting bounds startup
/// time and disk usage for long-lived corpora.
///
/// Must NOT be run against a directory a live server is using: both
/// processes would hold the same WAL open and this command truncates
/// it, destroying records the server already acknowledged.  Stop the
/// server first, or use the `save` wire op, which compacts in-process
/// under the server's own WAL lock.
fn cmd_compact(args: &Args) -> Result<()> {
    let mut cfg = match args.get("config") {
        Some(p) => ServeConfig::from_file(std::path::Path::new(p))?,
        None => ServeConfig::default(),
    };
    if let Some(d) = args.get("dir") {
        cfg.store.persist_dir = Some(PathBuf::from(d));
    }
    if let Some(k) = args.get_parsed::<usize>("num-hashes")? {
        cfg.num_hashes = k;
    }
    if let Some(s) = args.get("scheme") {
        cfg.sketch.scheme = SketchScheme::parse(s)?;
    }
    if let Some(b) = args.get_parsed::<u8>("bits")? {
        cfg.sketch.bits = b;
    }
    if let Some(s) = args.get_parsed::<usize>("shards")? {
        cfg.store.shards = s;
    }
    cfg.validate()?;
    let Some(dir) = cfg.store.persist_dir.clone() else {
        return Err(usage_err(
            "compact needs --dir or store.persist_dir in the config",
        ));
    };
    // Refuse to mint a fresh (possibly wrong-K) snapshot into a
    // directory with no prior state: compact has nothing of its own to
    // validate --num-hashes against, and a snapshot stamped with the
    // wrong K would block the real server from ever opening the dir.
    let has_snapshot = dir.join(cminhash::store::SNAPSHOT_FILE).exists();
    let has_wal = std::fs::metadata(dir.join(cminhash::store::WAL_FILE))
        .map(|m| m.len() > 0)
        .unwrap_or(false);
    if !has_snapshot && !has_wal {
        return Err(usage_err(format!(
            "{} holds no snapshot or WAL records; nothing to compact \
             (check --dir, and that --num-hashes/--scheme match the \
             serving config)",
            dir.display()
        )));
    }
    let t = Instant::now();
    let store = PersistentIndex::open_with_bits(
        cfg.num_hashes,
        cfg.sketch.scheme,
        cfg.sketch.bits,
        IndexConfig {
            bands: cfg.index.bands,
            rows_per_band: cfg.index.rows_per_band,
        },
        resolve_shards(cfg.store.shards),
        Some(&dir),
    )?;
    let bytes = store.compact()?;
    println!(
        "compacted {} sketches in {} -> {bytes} bytes in {:.1}ms",
        store.len(),
        dir.display(),
        t.elapsed().as_secs_f64() * 1e3
    );
    Ok(())
}

fn cmd_figures(args: &Args) -> Result<()> {
    let all = args.has("all");
    let fig = args.get_parsed::<u32>("fig")?;
    if fig.is_none() && !all {
        return Err(usage_err("pass --fig N or --all"));
    }
    let out = PathBuf::from(args.get("out").unwrap_or("results"));
    let t = Instant::now();
    cminhash::figures::run(if all { None } else { fig }, &out, args.has("fast"))?;
    println!("figures done in {:.1}s", t.elapsed().as_secs_f64());
    Ok(())
}

fn cmd_dataset(args: &Args) -> Result<()> {
    let kind = match args.require("kind")? {
        "nips" => CorpusKind::TextNips,
        "bbc" => CorpusKind::TextBbc,
        "mnist" => CorpusKind::ImageMnist,
        "cifar" => CorpusKind::ImageCifar,
        other => return Err(usage_err(format!("unknown kind {other} (nips|bbc|mnist|cifar)"))),
    };
    let n = args.get_parsed::<usize>("n")?.unwrap_or(100);
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(0);
    let out = PathBuf::from(args.require("out")?);
    let ds = kind.generate(n, seed);
    ds.save(&out)?;
    println!("wrote {} rows (D={}) to {}", ds.len(), ds.dim(), out.display());
    if args.has("stats") {
        println!("{:#?}", ds.stats(2000));
    }
    Ok(())
}

fn cmd_sketch(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.require("input")?);
    let out = PathBuf::from(args.require("out")?);
    let num_hashes = args.get_parsed::<usize>("num-hashes")?.unwrap_or(256);
    let seed = args.get_parsed::<u64>("seed")?.unwrap_or(42);
    let scheme = match args.get("scheme") {
        Some(s) => SketchScheme::parse(s)?,
        None => SketchScheme::Cmh,
    };
    // --bits b < 32 emits the masked low-b lanes — the values a packed
    // server (`serve --bits b`) stores and compares against.
    let bits = args.get_parsed::<u8>("bits")?.unwrap_or(32);
    cminhash::sketch::check_sketch_bits(bits)?;
    let ds = BinaryDataset::load(&input)?;
    let k = num_hashes.min(ds.dim() as usize);
    // Offline sketches are interchangeable with a server running the
    // same (scheme, D, K, seed); the scheme's own validation (e.g. the
    // OPH divisibility rule) surfaces here as a clean CLI error.
    let hasher = scheme.build(ds.dim() as usize, k, seed)?;
    let t = Instant::now();
    let mask = if bits == 32 { u32::MAX } else { (1u32 << bits) - 1 };
    let sketches: Vec<Vec<u32>> = ds
        .rows()
        .iter()
        .map(|r| {
            let mut sk = hasher.sketch_sparse(r.indices());
            if bits < 32 {
                for v in sk.iter_mut() {
                    *v &= mask;
                }
            }
            sk
        })
        .collect();
    let dt = t.elapsed();
    let json = cminhash::util::json::Json::Arr(
        sketches
            .iter()
            .map(|s| cminhash::util::json::Json::from_u32s(s))
            .collect(),
    );
    std::fs::write(&out, json.to_string())?;
    println!(
        "sketched {} rows (scheme={scheme}, bits={bits}, K={k}) in {:.1}ms \
         ({:.0} rows/s) -> {}",
        ds.len(),
        dt.as_secs_f64() * 1e3,
        ds.len() as f64 / dt.as_secs_f64(),
        out.display()
    );
    Ok(())
}

/// Cluster loadgen: synthesize `--requests` rows, ingest them through
/// rendezvous-routed `insert_batch` chunks, then issue fan-out queries
/// and report merged-query latency plus degradation (skipped nodes and
/// the client's `node_errors` counter).
fn cmd_loadgen_cluster(args: &Args, cfg_path: &str) -> Result<()> {
    let cfg = cminhash::server::ClusterConfig::load(std::path::Path::new(cfg_path))?;
    let requests = args.get_parsed::<usize>("requests")?.unwrap_or(1000);
    let dim = args.get_parsed::<u32>("dim")?.unwrap_or(4096);
    let nnz = args.get_parsed::<u32>("nnz")?.unwrap_or(64);
    let batch = args.get_parsed::<usize>("batch")?.unwrap_or(256).max(1);
    let topk = args.get_parsed::<usize>("topk")?.unwrap_or(10);
    let nodes = cfg.nodes.len();
    let mut client = cminhash::server::ClusterClient::connect(cfg)?;
    let mut rng = Rng::seed_from_u64(7);
    let mut row = || -> Vec<u32> {
        let mut idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, dim)).collect();
        idx.sort_unstable();
        idx.dedup();
        idx
    };
    let mut inserted = 0u64;
    let mut failed: Vec<String> = Vec::new();
    let t0 = Instant::now();
    let mut sent = 0usize;
    while sent < requests {
        let n = batch.min(requests - sent);
        let rows: Vec<Vec<u32>> = (0..n).map(|_| row()).collect();
        let out = client.insert_batch(dim, rows)?;
        inserted += out.inserted;
        for id in out.failed_nodes {
            if !failed.contains(&id) {
                failed.push(id);
            }
        }
        sent += n;
    }
    let ingest_secs = t0.elapsed().as_secs_f64();
    let queries = (requests / 10).clamp(1, 200);
    let mut lats = Vec::with_capacity(queries);
    let t1 = Instant::now();
    for _ in 0..queries {
        let t = Instant::now();
        let (_, degraded, failed_now) = client.query(dim, row(), topk)?;
        lats.push(t.elapsed().as_secs_f64() * 1e3);
        if degraded {
            for id in failed_now {
                if !failed.contains(&id) {
                    failed.push(id);
                }
            }
        }
    }
    let query_secs = t1.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.total_cmp(b));
    let q = |p: f64| lats[((lats.len() as f64 * p) as usize).min(lats.len() - 1)];
    let node_errors = client
        .metrics()
        .node_errors
        .load(std::sync::atomic::Ordering::Relaxed);
    println!(
        "cluster of {nodes}: inserted {inserted}/{requests} rows in {ingest_secs:.2}s \
         -> {:.0} rows/s",
        inserted as f64 / ingest_secs.max(1e-9),
    );
    println!(
        "{queries} fan-out queries in {query_secs:.2}s; latency ms p50={:.2} \
         p99={:.2} max={:.2}",
        q(0.50),
        q(0.99),
        lats[lats.len() - 1],
    );
    if failed.is_empty() && node_errors == 0 {
        println!("no degraded merges (node_errors=0)");
    } else {
        println!(
            "DEGRADED: nodes [{}] failed at least once (node_errors={node_errors})",
            failed.join(", ")
        );
    }
    Ok(())
}

// `join().expect` surfaces a loadgen-worker panic instead of folding a
// harness bug into a latency report.
#[allow(clippy::disallowed_methods)]
fn cmd_loadgen(args: &Args) -> Result<()> {
    if let Some(p) = args.get("cluster") {
        let p = p.to_string();
        return cmd_loadgen_cluster(args, &p);
    }
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878").to_string();
    let requests = args.get_parsed::<usize>("requests")?.unwrap_or(1000);
    let dim = args.get_parsed::<u32>("dim")?.unwrap_or(4096);
    let nnz = args.get_parsed::<u32>("nnz")?.unwrap_or(64);
    let conns = args.get_parsed::<usize>("conns")?.unwrap_or(4);
    let binary = args.has("binary");
    let per_conn = requests / conns.max(1);
    if per_conn == 0 {
        return Err(usage_err(format!(
            "--requests {requests} is fewer than --conns {conns}; nothing to send"
        )));
    }
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..conns {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            let mut client = BlockingClient::connect(&addr)?;
            if binary {
                client.binary()?;
            }
            let mut rng = Rng::seed_from_u64(c as u64);
            let mut lats = Vec::with_capacity(per_conn);
            for _ in 0..per_conn {
                let idx: Vec<u32> = (0..nnz).map(|_| rng.range_u32(0, dim)).collect();
                let t = Instant::now();
                let _ = client.sketch(dim, idx)?;
                lats.push(t.elapsed().as_secs_f64() * 1e3);
            }
            Ok(lats)
        }));
    }
    let mut lats: Vec<f64> = Vec::new();
    for h in handles {
        lats.extend(h.join().expect("loadgen thread panicked")?);
    }
    let wall = t0.elapsed().as_secs_f64();
    lats.sort_by(|a, b| a.total_cmp(b));
    let q = |p: f64| lats[((lats.len() as f64 * p) as usize).min(lats.len() - 1)];
    println!(
        "{} {} requests over {conns} conns in {wall:.2}s -> {:.0} req/s; \
         latency ms p50={:.2} p90={:.2} p99={:.2} max={:.2}",
        if binary { "bin1" } else { "jsonl" },
        lats.len(),
        lats.len() as f64 / wall,
        q(0.50),
        q(0.90),
        q(0.99),
        lats[lats.len() - 1],
    );
    // Server-side view of the same run: the sketch-latency histogram
    // excludes the network, so the gap between these numbers and the
    // client percentiles above is transport + queueing cost.
    match BlockingClient::connect(&addr)
        .and_then(|mut c| c.call_raw(&cminhash::server::protocol::Request::Stats))
    {
        Ok(raw) => {
            if let Ok(lat) = raw
                .get("metrics")
                .and_then(|m| m.get("sketch_latency"))
            {
                let f = |k: &str| lat.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
                println!(
                    "server-side sketch latency µs: count={:.0} mean={:.1} \
                     p50={:.0} p99={:.0} max={:.0}",
                    f("count"),
                    f("mean_us"),
                    f("p50_us"),
                    f("p99_us"),
                    f("max_us"),
                );
            }
        }
        Err(e) => eprintln!("note: could not fetch server-side stats: {e}"),
    }
    Ok(())
}

/// Fetch one stats snapshot from a running server.  Default output is
/// the raw JSON `stats` line (full histograms, per-shard counters, WAL
/// telemetry); `--prom` prints the Prometheus text exposition instead,
/// ready to pipe into a scrape file or `promtool check metrics`.
fn cmd_stats(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878");
    let mut client = BlockingClient::connect(addr)?;
    if args.has("prom") {
        print!("{}", client.metrics_text()?);
    } else {
        let raw = client.call_raw(&cminhash::server::protocol::Request::Stats)?;
        println!("{}", raw.to_string());
    }
    Ok(())
}

/// Live dashboard: poll a running server's `stats` every
/// `--interval-ms` and print one line per tick with per-op request
/// **rates** (deltas between polls divided by the poll gap — the
/// server only exports cumulative counters) plus current sketch/query
/// latency percentiles.  `--iters N` stops after N ticks (0 = run
/// until interrupted).  The first tick has no predecessor, so it
/// prints cumulative totals instead of rates.
fn cmd_top(args: &Args) -> Result<()> {
    use cminhash::util::json::Json;
    let addr = args.get("addr").unwrap_or("127.0.0.1:7878");
    let interval_ms = args.get_parsed::<u64>("interval-ms")?.unwrap_or(1000).max(1);
    let iters = args.get_parsed::<u64>("iters")?.unwrap_or(0);
    let mut client = BlockingClient::connect(addr)?;
    let mut prev: Option<(Instant, HashMap<String, f64>)> = None;
    let mut tick = 0u64;
    loop {
        let raw = client.call_raw(&cminhash::server::protocol::Request::Stats)?;
        let now = Instant::now();
        let counts: HashMap<String, f64> = match raw.get("requests")? {
            Json::Obj(m) => m
                .iter()
                .filter_map(|(k, v)| v.as_f64().ok().map(|n| (k.clone(), n)))
                .collect(),
            _ => {
                return Err(Error::Protocol(
                    "stats response lacks a requests object".into(),
                ))
            }
        };
        let metrics = raw.get("metrics")?;
        let lat = |hist: &str, field: &str| -> f64 {
            metrics
                .get(hist)
                .and_then(|h| h.get(field))
                .and_then(|v| v.as_f64())
                .unwrap_or(0.0)
        };
        let uptime = metrics.get("uptime_s").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let errors = metrics.get("errors").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let stored = raw.get("stored").and_then(|v| v.as_f64()).unwrap_or(0.0);
        let ops_col = match &prev {
            Some((t_prev, prev_counts)) => {
                let dt = now.duration_since(*t_prev).as_secs_f64().max(1e-9);
                let mut parts: Vec<String> = counts
                    .iter()
                    .filter_map(|(op, n)| {
                        let d = n - prev_counts.get(op).copied().unwrap_or(0.0);
                        (d > 0.0).then(|| format!("{op}={:.0}/s", d / dt))
                    })
                    .collect();
                parts.sort();
                if parts.is_empty() {
                    "idle".to_string()
                } else {
                    parts.join(" ")
                }
            }
            None => {
                let mut parts: Vec<String> = counts
                    .iter()
                    .filter_map(|(op, n)| (*n > 0.0).then(|| format!("{op}={n:.0}")))
                    .collect();
                parts.sort();
                if parts.is_empty() {
                    "no requests yet".to_string()
                } else {
                    format!("totals: {}", parts.join(" "))
                }
            }
        };
        println!(
            "up {uptime:.0}s stored={stored:.0} | {ops_col} | sketch µs \
             p50={:.0} p99={:.0} | query µs p50={:.0} p99={:.0} | errors={errors:.0}",
            lat("sketch_latency", "p50_us"),
            lat("sketch_latency", "p99_us"),
            lat("query_latency", "p50_us"),
            lat("query_latency", "p99_us"),
        );
        prev = Some((now, counts));
        tick += 1;
        if iters > 0 && tick >= iters {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(interval_ms));
    }
}

/// Print the paper's exact variance theory for a (D, f, a, K) point —
/// a quick calculator for capacity planning ("how big must K be?").
fn cmd_theory(args: &Args) -> Result<()> {
    use cminhash::theory::{var_minhash, var_sigma_pi, variance_ratio};
    let d = args.require_parsed::<usize>("d")?;
    let f = args.require_parsed::<usize>("f")?;
    let a = args.get_parsed::<usize>("a")?.unwrap_or(f / 2);
    let k = args.get_parsed::<usize>("k")?.unwrap_or_else(|| 256.min(d));
    if !((1..=d).contains(&f) && a <= f && (1..=d).contains(&k)) {
        return Err(usage_err("need a <= f <= D with f >= 1, and 1 <= K <= D"));
    }
    let j = a as f64 / f as f64;
    println!("D={d} f={f} a={a} K={k}  (J = {j:.4})");
    println!("  Var[J_MH]        = {:.6e}   (sd {:.4})", var_minhash(j, k), var_minhash(j, k).sqrt());
    let v = var_sigma_pi(d, f, a, k);
    println!("  Var[J_C-MinHash] = {v:.6e}   (sd {:.4})", v.sqrt());
    if let Some(r) = variance_ratio(d, f, a, k) {
        println!("  ratio            = {r:.4}x  (Theorem 3.4: always > 1)");
    }
    println!("  permutation memory: C-MinHash {} B vs classic {} B", 2 * 4 * d, k * 4 * d);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let artifacts = PathBuf::from(args.get("artifacts").unwrap_or("artifacts"));
    let m = Manifest::load(&artifacts)?;
    println!("{} artifacts in {}:", m.artifacts.len(), artifacts.display());
    for (name, meta) in &m.artifacts {
        let ins: Vec<String> = meta
            .inputs
            .iter()
            .map(|t| format!("{}:{:?}{}", t.name, t.shape, t.dtype))
            .collect();
        println!("  {name}  [{}]", ins.join(", "));
    }
    Ok(())
}
