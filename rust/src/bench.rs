//! Mini benchmark harness (criterion replacement for the offline
//! build): adaptive iteration count, warmup, mean/median/stddev over
//! timed batches, criterion-like one-line output, optional CSV dump.
//!
//! Used by every `rust/benches/*.rs` target (all `harness = false`).

use std::hint::black_box as bb;
use std::io::Write;
use std::time::{Duration, Instant};

pub use std::hint::black_box;

/// One benchmark's statistics (nanoseconds per iteration).
#[derive(Clone, Debug)]
pub struct Stats {
    /// Benchmark id.
    pub name: String,
    /// Mean ns/iter.
    pub mean_ns: f64,
    /// Median ns/iter.
    pub median_ns: f64,
    /// Standard deviation ns/iter.
    pub stddev_ns: f64,
    /// Total iterations measured.
    pub iters: u64,
}

impl Stats {
    /// Human-readable time with units.
    pub fn pretty(ns: f64) -> String {
        if ns < 1e3 {
            format!("{ns:.1} ns")
        } else if ns < 1e6 {
            format!("{:.2} µs", ns / 1e3)
        } else if ns < 1e9 {
            format!("{:.2} ms", ns / 1e6)
        } else {
            format!("{:.2} s", ns / 1e9)
        }
    }
}

/// A collection of benchmark runs with shared config.
pub struct Harness {
    title: String,
    target_time: Duration,
    samples: usize,
    results: Vec<Stats>,
}

impl Harness {
    /// New harness; honors `CMINHASH_BENCH_FAST=1` for quick smoke runs.
    pub fn new(title: &str) -> Self {
        let fast = std::env::var("CMINHASH_BENCH_FAST").is_ok_and(|v| v == "1");
        println!("== bench suite: {title} ==");
        Harness {
            title: title.to_string(),
            target_time: if fast {
                Duration::from_millis(100)
            } else {
                Duration::from_millis(700)
            },
            samples: if fast { 8 } else { 20 },
            results: Vec::new(),
        }
    }

    /// Time `f`, printing a criterion-style line.
    // `last().unwrap()` follows the push above — non-empty by construction.
    #[allow(clippy::disallowed_methods)]
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Stats {
        // Warmup + calibration: how many iters fit in target_time/samples?
        let t0 = Instant::now();
        let mut calib_iters = 0u64;
        while t0.elapsed() < Duration::from_millis(50) {
            bb(f());
            calib_iters += 1;
        }
        let per_iter = t0.elapsed().as_secs_f64() / calib_iters as f64;
        let batch = ((self.target_time.as_secs_f64() / self.samples as f64 / per_iter)
            .ceil() as u64)
            .max(1);

        let mut times: Vec<f64> = Vec::with_capacity(self.samples);
        let mut total_iters = 0u64;
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..batch {
                bb(f());
            }
            times.push(t.elapsed().as_nanos() as f64 / batch as f64);
            total_iters += batch;
        }
        times.sort_by(|a, b| a.total_cmp(b));
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let median = times[times.len() / 2];
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
            / times.len() as f64;
        let stats = Stats {
            name: name.to_string(),
            mean_ns: mean,
            median_ns: median,
            stddev_ns: var.sqrt(),
            iters: total_iters,
        };
        println!(
            "{:<48} time: [{} ± {}]  (median {}, {} iters)",
            name,
            Stats::pretty(stats.mean_ns),
            Stats::pretty(stats.stddev_ns),
            Stats::pretty(stats.median_ns),
            stats.iters
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Report a pre-measured quantity (e.g. one long end-to-end run).
    // `last().unwrap()` follows the push above — non-empty by construction.
    #[allow(clippy::disallowed_methods)]
    pub fn report(&mut self, name: &str, total: Duration, iters: u64) -> &Stats {
        let ns = total.as_nanos() as f64 / iters.max(1) as f64;
        let stats = Stats {
            name: name.to_string(),
            mean_ns: ns,
            median_ns: ns,
            stddev_ns: 0.0,
            iters,
        };
        println!(
            "{:<48} time: [{} /iter over {} iters]",
            name,
            Stats::pretty(ns),
            iters
        );
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Append results as CSV under `results/bench/<suite>.csv`.
    pub fn write_csv(&self) -> std::io::Result<()> {
        let dir = std::path::Path::new("results/bench");
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.csv", self.title.replace([' ', '/'], "_")));
        let mut f = std::io::BufWriter::new(std::fs::File::create(&path)?);
        writeln!(f, "name,mean_ns,median_ns,stddev_ns,iters")?;
        for s in &self.results {
            writeln!(
                f,
                "{},{},{},{},{}",
                s.name, s.mean_ns, s.median_ns, s.stddev_ns, s.iters
            )?;
        }
        Ok(())
    }

    /// Results so far.
    pub fn results(&self) -> &[Stats] {
        &self.results
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)] // tests assert freely
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something_reasonable() {
        std::env::set_var("CMINHASH_BENCH_FAST", "1");
        let mut h = Harness::new("selftest");
        let s = h.bench("noop-ish", || bb(1u64 + 1)).clone();
        assert!(s.mean_ns > 0.0 && s.mean_ns < 1e6);
        let s2 = h
            .bench("sleepless sum", || (0..1000u64).sum::<u64>())
            .clone();
        assert!(s2.iters > 0);
        assert_eq!(h.results().len(), 2);
    }

    #[test]
    fn pretty_units() {
        assert!(Stats::pretty(5.0).contains("ns"));
        assert!(Stats::pretty(5e3).contains("µs"));
        assert!(Stats::pretty(5e6).contains("ms"));
        assert!(Stats::pretty(5e9).contains(" s"));
    }
}
